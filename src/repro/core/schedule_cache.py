"""Content-addressed on-disk cache for trace graphs and tile schedules.

Repeated CLI / CI invocations of the trace backend (DESIGN.md §13) used
to regenerate every synthetic graph and re-derive every tile schedule
from scratch — at 10⁷ edges that is tens of seconds of pure recompute
per process.  This module gives :mod:`repro.core.trace` a small
content-addressed store:

* **Graphs** — the edge list plus the two sort factorizations a
  :class:`~repro.core.trace.GraphTrace` derives at construction (the
  dst-CSR order and the global ``(sender, receiver)`` lexsort), keyed by
  ``sha256({dataset, canonical params, cache token, format version})``.
* **Schedules** — the per-tile count arrays of one
  :class:`~repro.core.trace.TraceSchedule` (vertex / edge / halo / cut
  counts; O(n_tiles), tiny), keyed by the graph identity plus the tile
  capacity.  The ranked-pair cache-hit data is *not* stored — it is
  O(unique pairs) large and recomputed lazily from the trace on demand.

Only dataset builders registered with an explicit ``cache_token`` take
part (the token is the builder's manual version stamp: bumping it
invalidates every cached artifact of that dataset), so throwaway
in-memory datasets (``trace_scenarios_from_graph``, tests) can never be
served stale bytes.  Entries are written atomically (`os.replace`) and
are plain ``.npz`` files — safe to delete at any time.

Configuration (read per call, so tests can monkeypatch):

* ``REPRO_TRACE_CACHE`` — cache directory; ``0`` / ``off`` / empty
  disables; unset defaults to ``~/.cache/repro-trace``.
* ``REPRO_TRACE_CACHE_MIN_EDGES`` — smallest edge count worth a disk
  round trip (default 200000; small graphs rebuild faster than they
  deserialize).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Mapping, Optional

import numpy as np

__all__ = [
    "cache_root",
    "min_cached_edges",
    "graph_cache_key",
    "schedule_cache_key",
    "load_graph",
    "store_graph",
    "load_schedule",
    "store_schedule",
]

#: Bump when the on-disk layout of either artifact kind changes.
FORMAT_VERSION = 1

_DEFAULT_ROOT = "~/.cache/repro-trace"
_DEFAULT_MIN_EDGES = 200_000
_DISABLED = {"", "0", "off", "none", "disabled"}


def cache_root() -> Optional[Path]:
    """The cache directory, or None when disk caching is disabled."""
    raw = os.environ.get("REPRO_TRACE_CACHE")
    if raw is None:
        raw = _DEFAULT_ROOT
    if raw.strip().lower() in _DISABLED:
        return None
    return Path(raw).expanduser()


def min_cached_edges() -> int:
    raw = os.environ.get("REPRO_TRACE_CACHE_MIN_EDGES")
    if raw is None:
        return _DEFAULT_MIN_EDGES
    try:
        return max(0, int(raw))
    except ValueError:
        return _DEFAULT_MIN_EDGES


def _digest(payload: Mapping[str, Any]) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def graph_cache_key(dataset: str, canonical_params: str, token: str) -> str:
    return _digest({"kind": "graph", "dataset": dataset,
                    "params": canonical_params, "token": token,
                    "format": FORMAT_VERSION})


def schedule_cache_key(dataset: str, canonical_params: str, token: str,
                       capacity: int) -> str:
    return _digest({"kind": "schedule", "dataset": dataset,
                    "params": canonical_params, "token": token,
                    "capacity": int(capacity), "format": FORMAT_VERSION})


def _path_for(key: str) -> Optional[Path]:
    root = cache_root()
    if root is None:
        return None
    return root / key[:2] / f"{key}.npz"


def _atomic_savez(path: Path, **arrays) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _load_npz(key: str) -> Optional[dict]:
    path = _path_for(key)
    if path is None or not path.is_file():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            return {name: z[name] for name in z.files}
    except (OSError, ValueError, KeyError):
        # A torn or foreign file is a miss, never an error; drop it so the
        # next store rewrites a clean entry.
        try:
            path.unlink()
        except OSError:
            pass
        return None


def _compact_int(a: np.ndarray) -> np.ndarray:
    """int64 -> int32 when the values fit (halves cache size and load time)."""
    a = np.asarray(a)
    if a.dtype == np.int64 and (a.size == 0
                                or (a.min() >= np.iinfo(np.int32).min
                                    and a.max() <= np.iinfo(np.int32).max)):
        return a.astype(np.int32)
    return a


# -- graphs -----------------------------------------------------------------
def load_graph(key: str) -> Optional[dict]:
    """Stored edge list + factorizations, or None on miss.

    The four contract arrays come back int64 (the ``GraphTrace``
    invariant); the unique-pair factorization keeps its compact on-disk
    dtype (it is the bandwidth-critical operand of every per-capacity
    pass) except the multiplicity prefix, which is int64 by contract.
    """
    d = _load_npz(key)
    if d is None or "senders" not in d or "n_nodes" not in d:
        return None
    out = {"n_nodes": int(d["n_nodes"])}
    for name in ("senders", "receivers", "csr_senders", "row_ptr"):
        if name in d:
            out[name] = d[name].astype(np.int64, copy=False)
    for name in ("fact_u_snd", "fact_u_rcv"):
        if name in d:
            out[name] = d[name]
    if "fact_mult_prefix" in d:
        out["fact_mult_prefix"] = d["fact_mult_prefix"].astype(
            np.int64, copy=False)
    return out


def store_graph(key: str, *, n_nodes: int, senders, receivers,
                csr_senders, row_ptr, fact_u_snd=None, fact_u_rcv=None,
                fact_mult_prefix=None) -> bool:
    path = _path_for(key)
    if path is None:
        return False
    arrays = {
        "n_nodes": np.asarray(int(n_nodes), dtype=np.int64),
        "senders": _compact_int(senders),
        "receivers": _compact_int(receivers),
        "csr_senders": _compact_int(csr_senders),
        "row_ptr": _compact_int(row_ptr),
    }
    if (fact_u_snd is not None and fact_u_rcv is not None
            and fact_mult_prefix is not None):
        arrays["fact_u_snd"] = np.asarray(fact_u_snd)
        arrays["fact_u_rcv"] = np.asarray(fact_u_rcv)
        arrays["fact_mult_prefix"] = _compact_int(fact_mult_prefix)
    try:
        _atomic_savez(path, **arrays)
    except OSError:
        return False
    return True


# -- schedules --------------------------------------------------------------
_SCHEDULE_FIELDS = ("vertex_counts", "edge_counts", "halo_counts",
                    "remote_edge_counts")


def load_schedule(key: str) -> Optional[dict]:
    """Stored per-tile count arrays (float64) plus n_tiles/capacity/K."""
    d = _load_npz(key)
    if d is None or any(f not in d for f in _SCHEDULE_FIELDS):
        return None
    out = {f: d[f].astype(np.float64, copy=False) for f in _SCHEDULE_FIELDS}
    for scalar in ("n_tiles", "capacity", "K"):
        if scalar not in d:
            return None
        out[scalar] = int(d[scalar])
    return out


def store_schedule(key: str, *, n_tiles: int, capacity: int, K: int,
                   vertex_counts, edge_counts, halo_counts,
                   remote_edge_counts) -> bool:
    path = _path_for(key)
    if path is None:
        return False
    try:
        _atomic_savez(
            path,
            n_tiles=np.asarray(int(n_tiles), dtype=np.int64),
            capacity=np.asarray(int(capacity), dtype=np.int64),
            K=np.asarray(int(K), dtype=np.int64),
            vertex_counts=np.asarray(vertex_counts, dtype=np.float64),
            edge_counts=np.asarray(edge_counts, dtype=np.float64),
            halo_counts=np.asarray(halo_counts, dtype=np.float64),
            remote_edge_counts=np.asarray(remote_edge_counts,
                                          dtype=np.float64),
        )
    except OSError:
        return False
    return True
