"""Content-addressed on-disk cache for trace graphs and tile schedules.

Repeated CLI / CI invocations of the trace backend (DESIGN.md §13) used
to regenerate every synthetic graph and re-derive every tile schedule
from scratch — at 10⁷ edges that is tens of seconds of pure recompute
per process.  This module gives :mod:`repro.core.trace` a small
content-addressed store:

* **Graphs** — the unique-pair factorization plus CSR row pointer of a
  :class:`~repro.core.trace.GraphTrace` (and the raw edge list /
  CSR columns when the builder materialized them), keyed by
  ``sha256({dataset, canonical params, cache token, format version})``.
  Format v2 stores each array as its own ``.npy`` file inside an
  atomically renamed ``<key>.graph/`` directory, so a warm resolve
  memory-maps every array (``np.load(..., mmap_mode="r")``) instead of
  eagerly inflating an npz: resolve cost drops to directory stats plus
  npy header reads, and bytes are only paged in for the arrays a
  schedule query actually touches (DESIGN.md §14).
* **Schedules** — the per-tile count arrays of one
  :class:`~repro.core.trace.TraceSchedule` (vertex / edge / halo / cut
  counts; O(n_tiles), tiny), keyed by the graph identity plus the tile
  capacity, still a single ``.npz`` (mmap would cost more than it
  saves at this size).  The ranked-pair cache-hit data is *not* stored
  — it is O(unique pairs) large and recomputed lazily on demand.

Only dataset builders registered with an explicit ``cache_token`` take
part (the token is the builder's manual version stamp: bumping it
invalidates every cached artifact of that dataset), so throwaway
in-memory datasets (``trace_scenarios_from_graph``, tests) can never be
served stale bytes.  Entries are written to a temp name and
``os.replace``-renamed — safe to delete at any time; a torn or foreign
entry is a miss that gets dropped, never an error.

Configuration (read per call, so tests can monkeypatch):

* ``REPRO_TRACE_CACHE`` — cache directory; ``0`` / ``off`` / empty
  disables; unset defaults to ``~/.cache/repro-trace``.
* ``REPRO_TRACE_CACHE_MIN_EDGES`` — smallest edge count worth a disk
  round trip (default 200000; small graphs rebuild faster than they
  deserialize).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Mapping, Optional

import numpy as np

__all__ = [
    "cache_root",
    "min_cached_edges",
    "graph_cache_key",
    "schedule_cache_key",
    "load_graph",
    "store_graph",
    "load_schedule",
    "store_schedule",
    "cache_stats",
    "reset_cache_stats",
]

#: Bump when the on-disk layout of either artifact kind changes.  v2:
#: graphs became per-array ``.npy`` directories (mmap-lazy warm
#: resolves) with an optional edge list and a required factorization.
FORMAT_VERSION = 2

_DEFAULT_ROOT = "~/.cache/repro-trace"
_DEFAULT_MIN_EDGES = 200_000
_DISABLED = {"", "0", "off", "none", "disabled"}

#: Graph payload arrays that may appear as ``<name>.npy`` parts.
_GRAPH_ARRAYS = ("senders", "receivers", "csr_senders", "row_ptr",
                 "fact_u_snd", "fact_u_rcv", "fact_mult_prefix")

#: Process-wide hit/miss/store counters for the disk cache, bumped only
#: when caching is enabled (a disabled cache is not a miss).  The serve
#: engine (DESIGN.md §18) reads deltas of these per micro-batch window;
#: the lock makes the read-modify-write cycles exact under concurrency.
_CACHE_COUNTERS = {
    "graph_hits": 0,
    "graph_misses": 0,
    "graph_stores": 0,
    "schedule_hits": 0,
    "schedule_misses": 0,
    "schedule_stores": 0,
    "store_races": 0,   # benign lost store_graph renames (see store_graph)
}
_COUNTER_LOCK = threading.Lock()


def _count(name: str) -> None:
    with _COUNTER_LOCK:
        _CACHE_COUNTERS[name] += 1


def reset_cache_stats() -> None:
    """Zero the process-wide disk-cache counters (see :func:`cache_stats`)."""
    with _COUNTER_LOCK:
        for key in _CACHE_COUNTERS:
            _CACHE_COUNTERS[key] = 0


def cache_stats() -> dict:
    """Disk-cache observability: process counters plus an on-disk census.

    Returns ``{"enabled", "root", "counters", "entries", "bytes"}`` where
    ``entries`` counts ``*.graph`` directories and schedule ``*.npz``
    files currently under :func:`cache_root` and ``bytes`` sums their
    sizes.  The walk is **eviction-safe**: entries deleted concurrently
    (another process pruning the cache, a racing ``_drop_graph_dir``)
    are simply skipped, never an error — the census is a snapshot, not
    an invariant.
    """
    with _COUNTER_LOCK:
        counters = dict(_CACHE_COUNTERS)
    root = cache_root()
    out = {"enabled": root is not None,
           "root": str(root) if root is not None else None,
           "counters": counters,
           "entries": {"graphs": 0, "schedules": 0},
           "bytes": 0}
    if root is None or not root.is_dir():
        return out
    graphs = schedules = total = 0
    try:
        shards = list(root.iterdir())
    except OSError:
        return out
    for shard in shards:
        try:
            children = list(shard.iterdir()) if shard.is_dir() else []
        except OSError:
            continue  # shard pruned mid-walk
        for entry in children:
            try:
                if entry.name.endswith(".graph") and entry.is_dir():
                    graphs += 1
                    for part in entry.iterdir():
                        total += part.stat().st_size
                elif entry.suffix == ".npz" and entry.is_file():
                    schedules += 1
                    total += entry.stat().st_size
            except OSError:
                continue  # entry evicted mid-walk
    out["entries"] = {"graphs": graphs, "schedules": schedules}
    out["bytes"] = int(total)
    return out


def cache_root() -> Optional[Path]:
    """The cache directory, or None when disk caching is disabled."""
    raw = os.environ.get("REPRO_TRACE_CACHE")
    if raw is None:
        raw = _DEFAULT_ROOT
    if raw.strip().lower() in _DISABLED:
        return None
    return Path(raw).expanduser()


def min_cached_edges() -> int:
    raw = os.environ.get("REPRO_TRACE_CACHE_MIN_EDGES")
    if raw is None:
        return _DEFAULT_MIN_EDGES
    try:
        return max(0, int(raw))
    except ValueError:
        return _DEFAULT_MIN_EDGES


def _digest(payload: Mapping[str, Any]) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def graph_cache_key(dataset: str, canonical_params: str, token: str) -> str:
    return _digest({"kind": "graph", "dataset": dataset,
                    "params": canonical_params, "token": token,
                    "format": FORMAT_VERSION})


def schedule_cache_key(dataset: str, canonical_params: str, token: str,
                       capacity: int) -> str:
    return _digest({"kind": "schedule", "dataset": dataset,
                    "params": canonical_params, "token": token,
                    "capacity": int(capacity), "format": FORMAT_VERSION})


def _graph_dir(key: str) -> Optional[Path]:
    root = cache_root()
    if root is None:
        return None
    return root / key[:2] / f"{key}.graph"


def _schedule_path(key: str) -> Optional[Path]:
    root = cache_root()
    if root is None:
        return None
    return root / key[:2] / f"{key}.npz"


def _atomic_savez(path: Path, **arrays) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _load_npz(path: Optional[Path]) -> Optional[dict]:
    if path is None or not path.is_file():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            return {name: z[name] for name in z.files}
    except (OSError, ValueError, KeyError):
        # A torn or foreign file is a miss, never an error; drop it so the
        # next store rewrites a clean entry.
        try:
            path.unlink()
        except OSError:
            pass
        return None


def _compact_int(a: np.ndarray) -> np.ndarray:
    """int64 -> int32 when the values fit (halves cache size and load time)."""
    a = np.asarray(a)
    if a.dtype == np.int64 and (a.size == 0
                                or (a.min() >= np.iinfo(np.int32).min
                                    and a.max() <= np.iinfo(np.int32).max)):
        return a.astype(np.int32)
    return a


def _drop_graph_dir(path: Path) -> None:
    try:
        shutil.rmtree(path)
    except OSError:
        pass


# -- graphs -----------------------------------------------------------------
def load_graph(key: str) -> Optional[dict]:
    """Stored graph payload with **memory-mapped** arrays, or None on miss.

    Returns ``n_nodes`` / ``n_edges`` ints plus ``row_ptr`` (always) and
    whichever of the edge list, CSR columns, and unique-pair
    factorization were stored — every array an ``mmap_mode="r"`` view,
    so nothing is read beyond npy headers until a consumer indexes it.
    Compact on-disk dtypes are kept (:class:`~repro.core.trace.
    GraphTrace` promotes explicitly where int64 range is needed; the
    multiplicity prefix is re-widened by its consumer).
    """
    path = _graph_dir(key)
    if path is None:
        return None
    if not path.is_dir():
        _count("graph_misses")
        return None
    try:
        meta = json.loads((path / "meta.json").read_text())
        out = {"n_nodes": int(meta["n_nodes"]),
               "n_edges": int(meta["n_edges"])}
        for name in _GRAPH_ARRAYS:
            part = path / f"{name}.npy"
            if part.is_file():
                out[name] = np.load(part, mmap_mode="r",
                                    allow_pickle=False)
        complete = "row_ptr" in out and (
            all(f"fact_{n}" in out
                for n in ("u_snd", "u_rcv", "mult_prefix"))
            or ("senders" in out and "receivers" in out))
        if not complete:
            raise ValueError(f"incomplete graph entry: {sorted(out)}")
        _count("graph_hits")
        return out
    except (OSError, ValueError, KeyError):
        # Torn writes can't happen (the rename is atomic), so anything
        # unreadable here is foreign or damaged: drop it -> miss.
        _drop_graph_dir(path)
        _count("graph_misses")
        return None


def store_graph(key: str, *, n_nodes: int, n_edges: int, row_ptr,
                senders=None, receivers=None, csr_senders=None,
                fact_u_snd=None, fact_u_rcv=None,
                fact_mult_prefix=None) -> bool:
    """Persist a graph payload as an atomically renamed part directory.

    ``row_ptr`` plus either the factorization trio or the raw edge list
    is required (the invariant :func:`load_graph` enforces); everything
    else is optional.  ``row_ptr`` stays int64 on disk — it is the one
    array :class:`~repro.core.trace.GraphTrace` consumes at its contract
    dtype, and keeping it verbatim lets the mmap view stand in directly.
    """
    path = _graph_dir(key)
    if path is None:
        return False
    arrays = {"row_ptr": np.asarray(row_ptr, dtype=np.int64)}
    for name, a in (("senders", senders), ("receivers", receivers),
                    ("csr_senders", csr_senders),
                    ("fact_u_snd", fact_u_snd), ("fact_u_rcv", fact_u_rcv)):
        if a is not None:
            arrays[name] = _compact_int(a)
    if fact_mult_prefix is not None:
        arrays["fact_mult_prefix"] = _compact_int(fact_mult_prefix)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = Path(tempfile.mkdtemp(dir=path.parent, suffix=".tmp"))
        try:
            for name, a in arrays.items():
                np.save(tmp / f"{name}.npy", a, allow_pickle=False)
            (tmp / "meta.json").write_text(json.dumps(
                {"n_nodes": int(n_nodes), "n_edges": int(n_edges),
                 "format": FORMAT_VERSION}))
            if path.exists():
                # Concurrent writer won the rename race; its bytes are
                # identical (content-addressed), keep them.
                _drop_graph_dir(tmp)
                _count("store_races")
            else:
                try:
                    os.replace(tmp, path)
                except OSError:
                    # exists() -> replace() is a TOCTOU window: a racing
                    # writer can land the entry between the check and the
                    # rename, and os.replace onto a non-empty directory
                    # raises ENOTEMPTY.  Content-addressing makes the
                    # loser's bytes identical, so losing the race is a
                    # benign no-op — but only when the winner's entry is
                    # actually there; anything else is a real failure.
                    _drop_graph_dir(tmp)
                    if not path.exists():
                        raise
                    _count("store_races")
        except BaseException:
            _drop_graph_dir(tmp)
            raise
    except OSError:
        return False
    _count("graph_stores")
    return True


# -- schedules --------------------------------------------------------------
_SCHEDULE_FIELDS = ("vertex_counts", "edge_counts", "halo_counts",
                    "remote_edge_counts")


def load_schedule(key: str) -> Optional[dict]:
    """Stored per-tile count arrays (float64) plus n_tiles/capacity/K."""
    path = _schedule_path(key)
    if path is None:
        return None
    d = _load_npz(path)
    if d is None or any(f not in d for f in _SCHEDULE_FIELDS):
        _count("schedule_misses")
        return None
    out = {f: d[f].astype(np.float64, copy=False) for f in _SCHEDULE_FIELDS}
    for scalar in ("n_tiles", "capacity", "K"):
        if scalar not in d:
            _count("schedule_misses")
            return None
        out[scalar] = int(d[scalar])
    _count("schedule_hits")
    return out


def store_schedule(key: str, *, n_tiles: int, capacity: int, K: int,
                   vertex_counts, edge_counts, halo_counts,
                   remote_edge_counts) -> bool:
    path = _schedule_path(key)
    if path is None:
        return False
    try:
        _atomic_savez(
            path,
            n_tiles=np.asarray(int(n_tiles), dtype=np.int64),
            capacity=np.asarray(int(capacity), dtype=np.int64),
            K=np.asarray(int(K), dtype=np.int64),
            vertex_counts=np.asarray(vertex_counts, dtype=np.float64),
            edge_counts=np.asarray(edge_counts, dtype=np.float64),
            halo_counts=np.asarray(halo_counts, dtype=np.float64),
            remote_edge_counts=np.asarray(remote_edge_counts,
                                          dtype=np.float64),
        )
    except OSError:
        return False
    _count("schedule_stores")
    return True
