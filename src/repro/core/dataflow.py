"""Declarative dataflow layer: an accelerator described as data, not code.

The paper characterizes each accelerator as an ordered list of movement
levels (Tables III/IV).  Historically this repo transcribed each table into
a hand-written module of row functions; adding a third dataflow meant
copy-pasting a module.  This layer makes the table itself the artifact:

* :class:`MovementSpec` — one movement level: a name, a memory-hierarchy
  class, a *role* (what the traffic carries, used by the composition layer
  in :mod:`repro.core.compose`), and a closed form mapping
  ``(graph, hw) -> (data_bits, iterations)``.
* :class:`DataflowSpec` — an ordered tuple of movement specs plus a
  hardware-parameter factory.  One shared engine (:meth:`DataflowSpec.
  evaluate`) turns any spec into a :class:`~repro.core.terms.ModelOutput`;
  there is no per-accelerator evaluation code anymore.
* :class:`SpecModel` — adapter keeping the original
  :class:`~repro.core.terms.AcceleratorModel` class API on top of a spec.

Specs are registered by name in :mod:`repro.core.registry`, which is how
the sweep engine, validation, benchmarks, and examples resolve them.
All closed forms broadcast, so array-valued graph or hardware parameters
evaluate entire sweeps in one call (see DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Tuple

import numpy as np

from .terms import AcceleratorModel, ModelOutput, MovementTerm

__all__ = ["MovementSpec", "DataflowSpec", "SpecModel", "MOVEMENT_ROLES"]

#: What a movement level's traffic carries.  The composition layer keys its
#: inter-layer residency policy on ``vertex_in`` / ``vertex_out``.
MOVEMENT_ROLES = (
    "vertex_in",    # loads input vertex features into the array
    "vertex_out",   # writes output vertex features back out
    "edges",        # streams graph topology (edge lists / adjacency blocks)
    "weights",      # loads model weights
    "compute",      # on-array traffic of the compute stages
    "interphase",   # traffic through an intermediate (inter-phase) buffer
    "other",
)

#: Closed form of one movement level: (graph, hw) -> (data_bits, iterations).
MovementForm = Callable[[object, object], Tuple[np.ndarray, np.ndarray]]


@dataclass(frozen=True)
class MovementSpec:
    """One movement level of a dataflow, as a declarative record.

    ``audit_note`` is a unit-audit waiver (DESIGN.md §16): when set, the
    model auditor (:mod:`repro.analysis`) reports this movement's unit
    findings as *waived* instead of failing ``--strict``.  It exists for
    paper-verbatim transcriptions whose published forms mix units (the
    HyGCN Table IV rows); the note must say which table row is being
    transcribed and why the finding is expected.
    """

    name: str
    hierarchy: str
    form: MovementForm
    role: str = "other"
    audit_note: str | None = None

    def __post_init__(self) -> None:
        if self.role not in MOVEMENT_ROLES:
            raise ValueError(
                f"unknown role {self.role!r} for movement {self.name!r}; "
                f"expected one of {MOVEMENT_ROLES}"
            )

    def term(self, graph, hw) -> MovementTerm:
        bits, iterations = self.form(graph, hw)
        return MovementTerm(self.name, self.hierarchy, bits, iterations)

    def interior_at(self, layer: int, n_layers: int) -> bool:
        """Whether this movement is an *interior* activation transfer.

        In an ``n_layers``-deep composition, a ``vertex_out`` before the
        last layer or a ``vertex_in`` after the first carries an
        inter-layer activation — exactly the traffic a ``"resident"``
        policy keeps on-array (DESIGN.md §7).  Both composition engines
        (:class:`~repro.core.compose.MultiLayerModel` and the relational
        model) key their residency handling on this single predicate so
        they cannot drift apart.
        """
        return ((self.role == "vertex_out" and layer < n_layers - 1)
                or (self.role == "vertex_in" and layer > 0))


@dataclass(frozen=True)
class DataflowSpec:
    """A complete accelerator dataflow: ordered movement levels + defaults.

    ``hw_factory`` builds the accelerator's default hardware parameters
    (Table II right column, or this repo's extensions); passing an explicit
    ``hw`` to :meth:`evaluate` overrides it wholesale.

    ``runnable`` is the conformance hook (DESIGN.md §10): a zero-arg factory
    returning a kernel-analogue object (see :mod:`repro.core.conformance`)
    when the dataflow has a compilable Pallas/XLA counterpart whose measured
    HBM bytes can be pinned against these closed forms.  ``None`` (the
    default) means the dataflow is analytical-only — the paper's situation
    for EnGN/HyGCN, whose simulators are closed-source.  The factory is
    called lazily so specs stay importable without jax.

    ``unused_hw`` waives the model auditor's dead-hardware-parameter check
    (DESIGN.md §16) for declared Table II fields that no movement form
    reads — e.g. EnGN's ``M_prime``, which enters only the fitting-factor
    diagnostic, not any movement.  Every entry is a recorded decision the
    provenance table surfaces; an *undeclared* dead parameter fails
    ``python -m repro.analysis --strict``.
    """

    name: str
    movements: tuple[MovementSpec, ...]
    hw_factory: Callable[[], object]
    description: str = ""
    runnable: Callable[[], object] | None = None
    unused_hw: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        names = [m.name for m in self.movements]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate movement names in spec {self.name!r}: {names}")

    def resolve_hw(self, hw=None):
        return self.hw_factory() if hw is None else hw

    def evaluate(self, graph, hw=None, *, extra_meta: Mapping | None = None) -> ModelOutput:
        """The shared engine: run every movement form and assemble the output."""
        hw = self.resolve_hw(hw)
        terms = tuple(m.term(graph, hw) for m in self.movements)
        meta = {"hw": hw, "graph": graph, "spec": self}
        if extra_meta:
            meta = {**meta, **extra_meta}
        return ModelOutput(accelerator=self.name, terms=terms, meta=meta)

    def movement(self, name: str) -> MovementSpec:
        for m in self.movements:
            if m.name == name:
                return m
        raise KeyError(f"spec {self.name!r} has no movement {name!r}; "
                       f"available: {[m.name for m in self.movements]}")

    def by_role(self, role: str) -> tuple[MovementSpec, ...]:
        if role not in MOVEMENT_ROLES:
            raise ValueError(f"unknown role {role!r}")
        return tuple(m for m in self.movements if m.role == role)

    @property
    def has_runnable(self) -> bool:
        return self.runnable is not None

    def runnable_analogue(self):
        """Instantiate the registered kernel analogue (conformance hook)."""
        if self.runnable is None:
            raise ValueError(f"dataflow {self.name!r} declares no runnable "
                             "kernel analogue (runnable=None)")
        return self.runnable()


class SpecModel(AcceleratorModel):
    """Class-API adapter: an :class:`AcceleratorModel` backed by a spec.

    Subclasses set ``spec`` as a class attribute (EnGNModel, HyGCNModel);
    ad-hoc instances wrap any spec: ``SpecModel(registry.get("awb_gcn"))``.
    """

    spec: DataflowSpec

    def __init__(self, spec: DataflowSpec | None = None) -> None:
        if spec is not None:
            self.spec = spec
        if not isinstance(getattr(self, "spec", None), DataflowSpec):
            raise TypeError(f"{type(self).__name__} has no DataflowSpec bound")
        self.name = self.spec.name

    def evaluate(self, graph, hw=None) -> ModelOutput:
        return self.spec.evaluate(graph, hw)
