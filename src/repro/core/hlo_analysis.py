"""Extract collective-traffic ground truth from compiled HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but *not* collective
traffic, so (per the brief) we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and account every

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

op.  For each op we record the **result-shape bytes** and derive **wire bytes
per chip** using the ring-schedule algebra of :mod:`repro.core.tpu_model`
(e.g. an all-gather over group size g receives (g-1)/g of its result).

Async pairs (``all-gather-start`` / ``all-gather-done``) are counted once, on
the ``-start`` op.  Tuple-shaped (variadic) collectives sum their components.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Iterable, Mapping

__all__ = ["CollectiveOp", "CollectiveStats", "parse_collectives",
           "entry_boundary_bytes", "DTYPE_BYTES"]

DTYPE_BYTES: dict[str, float] = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[2,16,128]{2,1,0} all-gather(bf16[2,1,128]{2,1,0} %p), ...
#       %ar = (f32[128]{0}, f32[64]{0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\s*\("
)

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _shape_bytes(shape_text: str) -> float:
    """Bytes of one shape literal or a tuple of them."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_text):
        dtype = m.group("dtype")
        if dtype not in DTYPE_BYTES:
            continue  # token types etc.
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    """Participant count of the collective from its replica_groups attr."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return 1


@dataclass(frozen=True)
class CollectiveOp:
    kind: str
    result_bytes: float
    group_size: int
    line_no: int

    @property
    def wire_bytes_per_chip(self) -> float:
        g = self.group_size
        s = self.result_bytes
        if g <= 1 and self.kind != "collective-permute":
            return 0.0
        if self.kind == "all-gather":
            return s * (g - 1) / g
        if self.kind == "all-reduce":
            return 2.0 * s * (g - 1) / g
        if self.kind == "reduce-scatter":
            return s * (g - 1)          # operand = result * g
        if self.kind == "all-to-all":
            return s * (g - 1) / g
        if self.kind == "collective-permute":
            return s
        raise AssertionError(self.kind)


@dataclass
class CollectiveStats:
    ops: list[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes_per_chip(self) -> float:
        return sum(op.wire_bytes_per_chip for op in self.ops)

    @property
    def total_result_bytes(self) -> float:
        return sum(op.result_bytes for op in self.ops)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for op in self.ops:
            out[op.kind] = out.get(op.kind, 0.0) + op.wire_bytes_per_chip
        return out

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for op in self.ops:
            out[op.kind] = out.get(op.kind, 0) + 1
        return out

    def summary(self) -> dict[str, object]:
        return {
            "wire_bytes_per_chip": self.total_wire_bytes_per_chip,
            "n_collectives": len(self.ops),
            "by_kind": self.by_kind(),
            "counts": self.counts(),
        }


# The result capture is greedy and anchored on the line-final body brace:
# layout-annotated signatures ("-> (f32[128]{0}, f32[64]{0}) {", common in
# TPU dumps) contain shape-layout braces the lazy form would stop at.
_ENTRY_RE = re.compile(
    r"^ENTRY\s+\S+\s*\((?P<params>.*)\)\s*->\s*(?P<result>.+?)\s*\{\s*$",
    re.MULTILINE)


def entry_boundary_bytes(hlo_text: str) -> dict[str, float]:
    """Exact bytes crossing the executable boundary of an HLO module.

    Every entry parameter must be read from memory at least once and every
    result written once, so the ENTRY signature is a measurement floor no
    schedule can beat — and, for programs whose operands stream blockwise
    exactly once per distinct block, the precise HBM footprint.  The
    conformance subsystem (DESIGN.md §10) pins kernel-boundary traffic —
    notably the inter-phase buffer materialized between the unfused
    aggregate/combine pair — on these numbers.

    Returns ``{"param_bytes", "result_bytes", "total_bytes"}``.
    """
    m = _ENTRY_RE.search(hlo_text)
    if not m:
        raise ValueError("no ENTRY computation signature found in HLO text")
    param_bytes = _shape_bytes(m.group("params"))
    result_bytes = _shape_bytes(m.group("result"))
    return {
        "param_bytes": param_bytes,
        "result_bytes": result_bytes,
        "total_bytes": param_bytes + result_bytes,
    }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan optimized HLO and account every collective once."""
    stats = CollectiveStats()
    for i, line in enumerate(hlo_text.splitlines()):
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # paired with -start, already counted
        kind = m.group("kind")
        result_bytes = _shape_bytes(m.group("result"))
        if result_bytes == 0.0:
            continue
        g = _group_size(line)
        if kind == "collective-permute":
            g = max(g, 2)
        stats.ops.append(CollectiveOp(kind, result_bytes, g, i))
    return stats
