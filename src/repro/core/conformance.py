"""Measured-vs-modeled conformance: pin the closed forms to compiled bytes.

The paper (Sec. III) concedes that "validation of the data movement models
is difficult" because the accelerators' simulators are closed-source.  The
TPU adaptation has no such excuse: the XLA-compiled Pallas programs are
open ground truth.  This subsystem compares, per movement level where
attributable, the analytical predictions of every registered dataflow that
declares a runnable kernel analogue (``DataflowSpec.runnable``) against
byte measurements of the compiled programs, across a grid of operating
points.  Methodology recorded in DESIGN.md §10.

Measurement layers (each a ``ConformanceRecord.source``):

``block_schedule``
    The Pallas pipeline's DMA schedule, traced from the kernel's *own*
    grid + BlockSpec index maps (re-exported by the kernel modules'
    ``*_block_streams`` helpers): iterate the grid in launch order (last
    dimension fastest), evaluate each operand's index map, and count a
    block transfer whenever the block index changes — Pallas elides the
    copy when consecutive steps revisit the same block.  This is the HBM
    traffic the compiled kernel performs on hardware, and it attributes
    bytes to individual movement levels.
``entry_boundary``
    Exact operand/result bytes of each compiled executable, parsed from
    the optimized HLO ENTRY signature (:func:`~repro.core.hlo_analysis.
    entry_boundary_bytes`).  For the unfused aggregate/combine pair the
    inter-phase buffer crosses this boundary twice, so the fused-minus-
    unfused boundary delta measures exactly the paper's eliminated
    ``K*N*sigma + P_s*N*sigma`` terms.
``cost_analysis``
    ``compiled.cost_analysis()['bytes accessed']``.  On CPU the
    ``interpret=True`` lowering adds loop-machinery traffic, so this is
    asserted as a one-sided floor (measured >= boundary), not an equality;
    on a real TPU backend the same record tightens.
``hlo_collectives``
    Wire bytes from :func:`~repro.core.hlo_analysis.parse_collectives` —
    zero for these single-device programs, and the hook through which the
    sharded kernels of later PRs join the same harness.

Every record carries a *declared tolerance*: schedule and boundary sources
are exact algebra over identical block geometry, so their tolerance is a
float64 epsilon; one-sided sources declare the slack direction instead.

This dynamic harness has a static counterpart: ``repro.analysis``
(DESIGN.md §16) audits the same closed forms symbolically — unit
consistency, symbol provenance, float64-exactness bounds — without
compiling anything.  :func:`run_conformance` runs that audit as a
preflight so byte measurements are never taken against a model that is
already known to be mis-transcribed.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

from .dataflow import DataflowSpec
from .hlo_analysis import entry_boundary_bytes, parse_collectives
from .notation import GraphTileParams

__all__ = [
    "OperatingPoint",
    "ConformanceRecord",
    "ProgramMeasurement",
    "FusedSpMMAnalogue",
    "UnfusedSpMMAnalogue",
    "default_operating_points",
    "schedule_stream_bytes",
    "measure_program",
    "measure_analogue",
    "conformance_records",
    "interphase_delta_records",
    "run_conformance",
    "verify_numerics",
    "summarize_records",
    "EXACT_REL_TOL",
]

#: Declared tolerance for sources that are exact algebra in float64.
EXACT_REL_TOL = 1e-9


@dataclass(frozen=True)
class OperatingPoint:
    """One compile point of the kernel sweep: tile sizes in the paper's
    notation (K vertices, N in-features, T out-features) plus the kernel
    block shape — the node-block/feature/tile-size axes of the sweep."""

    K: int
    N: int
    T: int
    Bn: int
    Bk: int
    elem_bytes: float = 4.0   # f32 kernels; sigma = 8 * elem_bytes bits

    def __post_init__(self) -> None:
        if self.K % self.Bn or self.K % self.Bk:
            raise ValueError(f"K={self.K} must divide into Bn={self.Bn} / "
                             f"Bk={self.Bk} blocks (the kernels assert this)")

    @property
    def sigma_bits(self) -> float:
        return 8.0 * self.elem_bytes

    def graph(self) -> GraphTileParams:
        """The tile in Table II notation.  L and P do not enter the
        block-dense closed forms; they carry the paper's defaults."""
        return GraphTileParams(N=self.N, T=self.T, K=self.K,
                               L=self.K // 10, P=10 * self.K)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def default_operating_points() -> tuple[OperatingPoint, ...]:
    """The default conformance sweep: 10 points over node-block size K,
    feature width N, and kernel tile shape (Bn, Bk), including the
    single-source-block (nbk == 1) and single-dst-block (nbn == 1)
    schedules whose DMA elision degenerates."""
    pts = [OperatingPoint(K, N, 8, Bn, Bk)
           for K in (256, 512)
           for N in (16, 32)
           for Bn, Bk in ((128, 128), (128, 256))]
    pts.append(OperatingPoint(256, 16, 8, 256, 256))   # single block: all resident
    pts.append(OperatingPoint(512, 32, 8, 512, 128))   # nbn == 1: one dst row
    return tuple(pts)


@dataclass(frozen=True)
class ConformanceRecord:
    """One analytical-vs-measured comparison with a declared tolerance."""

    dataflow: str
    movement: str          # movement-level name or an aggregate probe
    source: str            # block_schedule | entry_boundary | cost_analysis | hlo_collectives
    point: Mapping
    analytical_bytes: float
    measured_bytes: float
    tolerance: float
    one_sided: bool = False   # pass iff measured >= analytical * (1 - tol)

    @property
    def ratio(self) -> float:
        """measured / analytical (1.0 when both sides are zero)."""
        if self.analytical_bytes == 0.0:
            return 1.0 if self.measured_bytes == 0.0 else float("inf")
        return self.measured_bytes / self.analytical_bytes

    @property
    def ok(self) -> bool:
        if self.one_sided:
            return self.measured_bytes >= self.analytical_bytes * (1.0 - self.tolerance)
        if self.analytical_bytes == 0.0:
            return self.measured_bytes == 0.0
        return abs(self.ratio - 1.0) <= self.tolerance

    def as_row(self) -> dict:
        row = {"dataflow": self.dataflow, "movement": self.movement,
               "source": self.source,
               "analytical_bytes": self.analytical_bytes,
               "measured_bytes": self.measured_bytes,
               "ratio": self.ratio, "tolerance": self.tolerance,
               "one_sided": self.one_sided, "ok": self.ok}
        row.update({k: v for k, v in dict(self.point).items()})
        return row

    def __str__(self) -> str:  # pragma: no cover - repr
        flag = "OK " if self.ok else "FAIL"
        return (f"[{flag}] {self.dataflow}.{self.movement} ({self.source}): "
                f"analytical={self.analytical_bytes:.6g}B "
                f"measured={self.measured_bytes:.6g}B ratio={self.ratio:.4f}")


@dataclass(frozen=True)
class ProgramMeasurement:
    """One compiled program plus its movement-attributed stream geometry."""

    label: str
    compiled: object                 # jax.stages.Compiled
    grid: tuple[int, ...]
    streams: Mapping[str, Mapping]   # movement name -> stream descriptor


def schedule_stream_bytes(grid: Sequence[int], stream: Mapping) -> dict:
    """Trace one operand's DMA schedule over the launch grid.

    Grid steps iterate in launch order (last dimension fastest).  A block
    transfer is counted whenever the evaluated index map differs from the
    previous step's — the Pallas pipeline skips the copy on revisits.
    Returns ``{"bytes", "transfers", "distinct_bytes", "distinct_blocks"}``;
    ``distinct_bytes`` is the union footprint (each block once), i.e. the
    executable-boundary share of this operand.
    """
    index_map: Callable = stream["index_map"]
    block_elems = math.prod(int(d) for d in stream["block_shape"])
    block_bytes = block_elems * float(stream["elem_bytes"])
    prev = None
    transfers = 0
    distinct: set[tuple] = set()
    for step in np.ndindex(*tuple(int(g) for g in grid)):
        idx = tuple(int(v) for v in index_map(*step))
        if idx != prev:
            transfers += 1
            prev = idx
        distinct.add(idx)
    return {
        "bytes": transfers * block_bytes,
        "transfers": transfers,
        "distinct_bytes": len(distinct) * block_bytes,
        "distinct_blocks": len(distinct),
    }


def measure_program(pm: ProgramMeasurement) -> dict:
    """All measurement layers for one compiled program."""
    hlo_text = pm.compiled.as_text()
    boundary = entry_boundary_bytes(hlo_text)
    collectives = parse_collectives(hlo_text)
    cost = pm.compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    per_stream = {name: schedule_stream_bytes(pm.grid, s)
                  for name, s in pm.streams.items()}
    return {
        "label": pm.label,
        "streams": per_stream,
        "stream_total_bytes": sum(s["bytes"] for s in per_stream.values()),
        "distinct_total_bytes": sum(s["distinct_bytes"]
                                    for s in per_stream.values()),
        "boundary": boundary,
        "collective_wire_bytes": collectives.total_wire_bytes_per_chip,
        "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }


class _SpMMAnalogueBase:
    """Shared machinery of the fused/unfused kernel analogues.

    Subclasses declare ``dataflow`` (the registered spec name) and
    ``programs(point, interpret=...)`` returning the compiled programs with
    their stream geometry.  Programs are lowered from abstract
    ``ShapeDtypeStruct`` operands — conformance measures compiled
    artifacts, so no input data ever materializes.
    """

    dataflow: str

    def graph_hw(self, spec: DataflowSpec, point: OperatingPoint):
        """The (graph, hw) pair putting the spec at the kernel's operating
        point: kernel dtype width as sigma, kernel blocks as Bn/Bk."""
        hw = spec.resolve_hw().replace(sigma=point.sigma_bits,
                                       sigma_adj=point.sigma_bits,
                                       Bn=point.Bn, Bk=point.Bk)
        return point.graph(), hw

    @staticmethod
    def _compile(fn, *shapes, **kwargs):
        import functools

        import jax
        jitted = jax.jit(functools.partial(fn, **kwargs))
        return jitted.lower(*shapes).compile()

    @staticmethod
    def _f32(*shape):
        import jax
        import jax.numpy as jnp
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    def programs(self, point: OperatingPoint, *,
                 interpret: bool = True) -> tuple[ProgramMeasurement, ...]:
        raise NotImplementedError


class FusedSpMMAnalogue(_SpMMAnalogueBase):
    """The fused aggregate+combine kernel <-> the ``spmm_tiled`` dataflow."""

    dataflow = "spmm_tiled"

    def programs(self, point: OperatingPoint, *,
                 interpret: bool = True) -> tuple[ProgramMeasurement, ...]:
        from ..kernels import edge_aggregate as ea
        K, N, T = point.K, point.N, point.T
        compiled = self._compile(
            ea.fused_aggregate_combine,
            self._f32(K, K), self._f32(K, N), self._f32(N, T),
            block_n=point.Bn, block_k=point.Bk, interpret=interpret)
        acct = ea.fused_block_streams(K, N, T, block_n=point.Bn,
                                      block_k=point.Bk,
                                      elem_bytes=point.elem_bytes)
        return (ProgramMeasurement("fused", compiled, acct["grid"],
                                   acct["streams"]),)


class UnfusedSpMMAnalogue(_SpMMAnalogueBase):
    """The two-pass kernel pair <-> the ``spmm_unfused`` dataflow."""

    dataflow = "spmm_unfused"

    def programs(self, point: OperatingPoint, *,
                 interpret: bool = True) -> tuple[ProgramMeasurement, ...]:
        from ..kernels import edge_aggregate_unfused as eu
        K, N, T = point.K, point.N, point.T
        agg = self._compile(
            eu.aggregate_pass, self._f32(K, K), self._f32(K, N),
            block_n=point.Bn, block_k=point.Bk, interpret=interpret)
        agg_acct = eu.aggregate_block_streams(K, N, block_n=point.Bn,
                                              block_k=point.Bk,
                                              elem_bytes=point.elem_bytes)
        comb = self._compile(
            eu.combine_pass, self._f32(K, N), self._f32(N, T),
            block_n=point.Bn, interpret=interpret)
        comb_acct = eu.combine_block_streams(K, N, T, block_n=point.Bn,
                                             elem_bytes=point.elem_bytes)
        return (
            ProgramMeasurement("aggregate", agg, agg_acct["grid"],
                               agg_acct["streams"]),
            ProgramMeasurement("combine", comb, comb_acct["grid"],
                               comb_acct["streams"]),
        )


def measure_analogue(analogue, point: OperatingPoint, *,
                     interpret: bool = True) -> list[dict]:
    """Compile + measure every program of one analogue at one point.
    Compilation dominates the sweep cost — callers sharing a point should
    measure once and pass the result to the record builders."""
    return [measure_program(pm)
            for pm in analogue.programs(point, interpret=interpret)]


def conformance_records(spec: DataflowSpec, point: OperatingPoint, *,
                        interpret: bool = True, analogue=None,
                        measures: list[dict] | None = None
                        ) -> list[ConformanceRecord]:
    """All conformance records of one dataflow at one operating point."""
    analogue = spec.runnable_analogue() if analogue is None else analogue
    graph, hw = analogue.graph_hw(spec, point)
    out = spec.evaluate(graph, hw)
    if measures is None:
        measures = measure_analogue(analogue, point, interpret=interpret)
    pt = point.as_dict()
    records: list[ConformanceRecord] = []

    # Per movement level where attributable: the traced DMA schedule.
    for meas in measures:
        for movement, traced in meas["streams"].items():
            records.append(ConformanceRecord(
                dataflow=spec.name, movement=movement,
                source="block_schedule", point=pt,
                analytical_bytes=float(out[movement].data_bits) / 8.0,
                measured_bytes=traced["bytes"],
                tolerance=EXACT_REL_TOL))

    # Off-chip total: every L2-class level must be covered by some stream.
    traced_total = sum(m["stream_total_bytes"] for m in measures)
    records.append(ConformanceRecord(
        dataflow=spec.name, movement="hbm_total", source="block_schedule",
        point=pt,
        analytical_bytes=float(out.offchip_bits()) / 8.0,
        measured_bytes=traced_total, tolerance=EXACT_REL_TOL))

    # Executable boundary: the compiled artifact's operand/result footprint
    # must equal the block cover of the declared streams.
    for meas in measures:
        records.append(ConformanceRecord(
            dataflow=spec.name, movement=f"boundary_{meas['label']}",
            source="entry_boundary", point=pt,
            analytical_bytes=meas["distinct_total_bytes"],
            measured_bytes=meas["boundary"]["total_bytes"],
            tolerance=EXACT_REL_TOL))

    # XLA's own accounting can only exceed the boundary floor.
    for meas in measures:
        records.append(ConformanceRecord(
            dataflow=spec.name, movement=f"xla_bytes_{meas['label']}",
            source="cost_analysis", point=pt,
            analytical_bytes=meas["boundary"]["total_bytes"],
            measured_bytes=meas["xla_bytes_accessed"],
            tolerance=0.0, one_sided=True))

    # Single-device programs move no collective bytes; the record keeps the
    # hlo_analysis hook live for the sharded kernels of later PRs.
    records.append(ConformanceRecord(
        dataflow=spec.name, movement="collective_wire",
        source="hlo_collectives", point=pt,
        analytical_bytes=0.0,
        measured_bytes=sum(m["collective_wire_bytes"] for m in measures),
        tolerance=0.0))
    return records


def interphase_delta_records(point: OperatingPoint, *, interpret: bool = True,
                             fused_measures: list[dict] | None = None,
                             unfused_measures: list[dict] | None = None
                             ) -> list[ConformanceRecord]:
    """Fused-minus-unfused measured bytes == the eliminated inter-phase terms.

    The paper's fusion claim (Sec. III / DESIGN.md §3): collapsing the
    inter-phase buffer into registers removes ``K*N*sigma`` write +
    ``P_s*N*sigma`` read traffic (``P_s = K`` in the block-dense analogue).
    Measured twice — at the executable boundary and in the traced DMA
    schedule — against ``spmm_unfused``'s analytical interphase levels.
    ``*_measures`` accept already-measured programs for this point
    (:func:`measure_analogue`) to avoid recompiling them.
    """
    from . import registry

    fused_spec = registry.get("spmm_tiled")
    unfused_spec = registry.get("spmm_unfused")
    fused = (fused_measures if fused_measures is not None else
             measure_analogue(fused_spec.runnable_analogue(), point,
                              interpret=interpret))
    unf_analogue = unfused_spec.runnable_analogue()
    unfused = (unfused_measures if unfused_measures is not None else
               measure_analogue(unf_analogue, point, interpret=interpret))
    graph, hw = unf_analogue.graph_hw(unfused_spec, point)
    out = unfused_spec.evaluate(graph, hw)
    eliminated = (float(out["writeinterphase"].data_bits)
                  + float(out["readinterphase"].data_bits)) / 8.0
    pt = point.as_dict()

    def _delta(key: Callable[[dict], float]) -> float:
        return sum(key(m) for m in unfused) - sum(key(m) for m in fused)

    return [
        ConformanceRecord(
            dataflow="spmm_unfused", movement="interphase_delta",
            source="entry_boundary", point=pt,
            analytical_bytes=eliminated,
            measured_bytes=_delta(lambda m: m["boundary"]["total_bytes"]),
            tolerance=EXACT_REL_TOL),
        ConformanceRecord(
            dataflow="spmm_unfused", movement="interphase_delta",
            source="block_schedule", point=pt,
            analytical_bytes=eliminated,
            measured_bytes=_delta(lambda m: m["stream_total_bytes"]),
            tolerance=EXACT_REL_TOL),
    ]


def run_conformance(names: Iterable[str] | None = None,
                    points: Sequence[OperatingPoint] | None = None, *,
                    interpret: bool = True,
                    include_delta: bool = True,
                    preflight_audit: bool = True) -> list[ConformanceRecord]:
    """The full harness: every runnable dataflow x every operating point.

    With ``preflight_audit`` (the default) each dataflow is first passed
    through the static model auditor (``repro.analysis``, DESIGN.md §16)
    and the harness refuses to measure a model whose closed forms fail
    the unit/provenance/golden audit — dynamic conformance numbers for a
    statically broken model would only lend it false credibility.
    """
    from . import registry

    if names is None:
        names = [s.name for s in registry.specs() if s.has_runnable]
    else:
        names = list(names)
    if preflight_audit:
        from repro.analysis import audit_spec

        for name in names:
            errors = audit_spec(registry.get(name)).strict_errors()
            if errors:
                raise AssertionError(
                    f"static model audit failure for {name!r}; refusing to "
                    "measure (rerun with preflight_audit=False to override): "
                    + "; ".join(errors))
    points = default_operating_points() if points is None else points
    records: list[ConformanceRecord] = []
    measured: dict[tuple[str, OperatingPoint], list[dict]] = {}
    for name in names:
        spec = registry.get(name)
        analogue = spec.runnable_analogue()
        for pt in points:
            measures = measure_analogue(analogue, pt, interpret=interpret)
            measured[(name, pt)] = measures
            records.extend(conformance_records(spec, pt, interpret=interpret,
                                               analogue=analogue,
                                               measures=measures))
    if include_delta and {"spmm_tiled", "spmm_unfused"} <= set(names):
        for pt in points:
            records.extend(interphase_delta_records(
                pt, interpret=interpret,
                fused_measures=measured[("spmm_tiled", pt)],
                unfused_measures=measured[("spmm_unfused", pt)]))
    return records


def verify_numerics(point: OperatingPoint, *, seed: int = 0,
                    interpret: bool = True) -> float:
    """Execute fused and unfused kernels at a point against the jnp oracle;
    returns the max relative error (conformance measures programs that
    compute the right thing, not just programs that move the right bytes)."""
    import jax.numpy as jnp

    from ..kernels import ops
    from ..kernels.ref import fused_aggregate_combine_ref

    rng = np.random.default_rng(seed)
    K, N, T = point.K, point.N, point.T
    a = jnp.asarray((rng.random((K, K)) < 0.05) * rng.random((K, K)),
                    jnp.float32)
    x = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((N, T)), jnp.float32)
    expect = fused_aggregate_combine_ref(a, x, w)
    fused = ops.gnn_aggregate_combine(a, x, w, block_n=point.Bn,
                                      block_k=point.Bk, interpret=interpret)
    unfused = ops.gnn_combine(
        ops.gnn_aggregate(a, x, block_n=point.Bn, block_k=point.Bk,
                          interpret=interpret),
        w, block_n=point.Bn, interpret=interpret)
    denom = float(jnp.max(jnp.abs(expect))) + 1e-9
    return max(float(jnp.max(jnp.abs(fused - expect))) / denom,
               float(jnp.max(jnp.abs(unfused - expect))) / denom)


def summarize_records(records: Sequence[ConformanceRecord]) -> dict:
    """Aggregate a record batch into the BENCH_conformance.json summary."""
    by_flow: dict[str, dict] = {}
    for r in records:
        e = by_flow.setdefault(r.dataflow, {"n_records": 0, "n_ok": 0,
                                            "max_abs_rel_err": 0.0})
        e["n_records"] += 1
        e["n_ok"] += int(r.ok)
        if not r.one_sided and np.isfinite(r.ratio):
            e["max_abs_rel_err"] = max(e["max_abs_rel_err"],
                                       abs(r.ratio - 1.0))
    return {
        "n_records": len(records),
        "n_ok": sum(int(r.ok) for r in records),
        "all_ok": all(r.ok for r in records),
        "by_dataflow": by_flow,
    }
