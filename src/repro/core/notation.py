"""Notation of the paper (Table II), as typed parameter records.

Every quantity is kept in the paper's units:

* feature-vector sizes ``N`` (input) and ``T`` (output) are *element counts*,
* ``sigma`` is the bit precision of one element,
* ``B`` is the L2 memory bandwidth in **bits per iteration** (the paper's
  iteration-granular bandwidth model),
* PE counts ``M``/``M'`` (EnGN array) and ``Ma``/``Mc`` (HyGCN engines) are
  numbers of processing elements.

All records are plain dataclasses of scalars *or* numpy arrays — the closed
forms in :mod:`repro.core.engn` / :mod:`repro.core.hygcn` broadcast, so a sweep
is expressed by passing an array for the swept field (see
:mod:`repro.core.sweep`).  Exact integer-valued float64 math is used throughout
(ceil-of-ratio terms must not suffer float32 rounding at K ~ 10^6).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Union

import numpy as np

ParamArray = Union[int, float, np.ndarray]

__all__ = [
    "ParamArray",
    "GraphTileParams",
    "RelationalScheduleParams",
    "CompositionHardwareParams",
    "EnGNHardwareParams",
    "HyGCNHardwareParams",
    "TiledSpMMHardwareParams",
    "AWBGCNHardwareParams",
    "PAPER_DEFAULT_GRAPH",
    "PAPER_DEFAULT_ENGN",
    "PAPER_DEFAULT_HYGCN",
    "paper_default_graph",
    "FieldUnit",
    "UNIT_DECLARATIONS",
    "declare_units",
    "unit_declarations_for",
]


# ---------------------------------------------------------------------------
# Unit declarations (consumed by :mod:`repro.analysis`, DESIGN.md §16)
#
# Every Table II symbol is declared with (a) a unit tag and (b) the operating
# envelope the static auditor propagates interval bounds over.  The paper's
# iteration-granular convention is encoded here once: ``bits`` and
# ``bits/iter`` both reduce to the ``bits`` dimension (B is the payload one
# iteration can move), while counts (``elements``/``vertices``/``edges``/
# ``PEs``) are dimensionless multipliers — so every Table III/IV data-movement
# form must reduce to bits^1 and every iteration form to bits^0.  A dropped
# ``sigma`` factor breaks that reduction (count x count products are not
# bits), which is exactly what the auditor hard-fails on.
#
# Graph symbols carry the ROADMAP item-1 operating envelope (10^9 edges /
# 10^7 vertices); hardware symbols default to ``lo=hi=None``, meaning the
# auditor pins them to the spec's own ``hw_factory()`` defaults (a point
# interval at the published design point).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FieldUnit:
    """Unit + envelope declaration of one parameter-record field.

    ``unit`` is one of the Table II tags: ``"bits"``, ``"bits/iter"``,
    ``"elements"``, ``"vertices"``, ``"edges"``, ``"PEs"``,
    ``"dimensionless"``.  ``lo``/``hi`` bound the field over the audited
    operating envelope; ``None`` means "pin to the record's default value".
    """

    unit: str
    lo: float | None = None
    hi: float | None = None
    doc: str = ""


#: record type -> {field name -> FieldUnit}.  Extend via :func:`declare_units`.
UNIT_DECLARATIONS: dict[type, dict[str, FieldUnit]] = {}


def declare_units(record_type: type, fields: dict[str, FieldUnit],
                  *, overwrite: bool = False) -> None:
    """Register unit declarations for a parameter-record dataclass.

    Third-party dataflow specs whose hardware records are not declared here
    must call this before :func:`repro.analysis.audit_spec` can trace them.
    """
    if record_type in UNIT_DECLARATIONS and not overwrite:
        raise ValueError(f"unit declarations for {record_type.__name__} "
                         "already registered (pass overwrite=True)")
    declared = set(fields)
    actual = {f.name for f in dataclasses.fields(record_type)}
    if declared != actual:
        raise ValueError(
            f"unit declarations for {record_type.__name__} must cover every "
            f"field exactly once; missing={sorted(actual - declared)} "
            f"extra={sorted(declared - actual)}")
    UNIT_DECLARATIONS[record_type] = dict(fields)


def unit_declarations_for(record) -> dict[str, FieldUnit]:
    """Resolve the declaration table for a record instance (exact type)."""
    try:
        return UNIT_DECLARATIONS[type(record)]
    except KeyError:
        raise KeyError(
            f"no unit declarations for parameter record type "
            f"{type(record).__name__}; call repro.core.notation."
            f"declare_units({type(record).__name__}, {{...}}) so the "
            f"analysis auditor can trace specs using it") from None


def _f64(x: ParamArray) -> np.ndarray:
    """Promote a parameter to float64 (exact for all integer magnitudes used)."""
    return np.asarray(x, dtype=np.float64)


@dataclass(frozen=True)
class GraphTileParams:
    """Input-graph parameters of a single tile (Table II, left column).

    Attributes:
      N: size of the input feature vector (elements).
      T: size of the output feature vector (elements).
      K: number of vertices in the tile.
      L: number of high-degree vertices in the tile (served by EnGN's
         dedicated L2* vertex cache).  The paper gives no default; we follow
         its "highly-connected vertices" narrative with L = K/10 unless
         overridden (see :func:`paper_default_graph`).
      P: number of edges in the tile.
    """

    N: ParamArray
    T: ParamArray
    K: ParamArray
    L: ParamArray
    P: ParamArray

    def replace(self, **kw: ParamArray) -> "GraphTileParams":
        return dataclasses.replace(self, **kw)

    def astuple_f64(self) -> tuple[np.ndarray, ...]:
        return tuple(_f64(v) for v in (self.N, self.T, self.K, self.L, self.P))


@dataclass(frozen=True)
class EnGNHardwareParams:
    """EnGN architecture parameters (Table II, right column).

    Attributes:
      sigma: bit precision of a feature element.
      B: L2 memory-bank bandwidth, bits/iteration.
      B_star: dedicated high-degree vertex-cache (L2*) bandwidth,
        bits/iteration.  Not given a default in the paper; defaults to ``B``.
      M: PE-array rows (vertices processed concurrently).
      M_prime: PE-array columns. EnGN default array is 128 x 16.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    B_star: ParamArray | None = None
    M: ParamArray = 128
    M_prime: ParamArray = 16

    @property
    def b_star(self) -> np.ndarray:
        return _f64(self.B if self.B_star is None else self.B_star)

    def replace(self, **kw: ParamArray) -> "EnGNHardwareParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class HyGCNHardwareParams:
    """HyGCN architecture parameters (Table II, right column).

    Attributes:
      sigma: bit precision.
      B: L2 memory bandwidth, bits/iteration.
      Ma: aggregation-engine PEs (32 SIMD cores, each covering up to 8
          feature components per step — the ``Ma * 8`` term in Table IV).
      Mc: combination-engine PEs (systolic array, 8 x 4 x 128 = 4096).
      gamma: systolic-array weight-reuse factor, 0 <= gamma < 1.
      Ps_ratio: edges remaining after HyGCN's window sliding, as a fraction
          of P.  The paper sets P_s ~ P, i.e. ratio 1.0.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    Ma: ParamArray = 32
    Mc: ParamArray = 8 * 4 * 128
    gamma: ParamArray = 0.5
    Ps_ratio: ParamArray = 1.0

    def Ps(self, P: ParamArray) -> np.ndarray:
        return _f64(P) * _f64(self.Ps_ratio)

    def replace(self, **kw: ParamArray) -> "HyGCNHardwareParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TiledSpMMHardwareParams:
    """Generic tiled block-dense SpMM accelerator (this repo's extension).

    The TPU/Pallas analogue of the paper's dataflows: the adjacency is tiled
    into (Bn x Bk) dense blocks and aggregation+combination are fused on one
    matrix unit, so no inter-phase buffer term exists (DESIGN.md §3/§7).
    ``Bn``/``Bk`` mirror ``DEFAULT_BLOCK_N``/``DEFAULT_BLOCK_K`` of
    :mod:`repro.kernels.edge_aggregate` — keep them in sync (asserted in
    tests when jax is importable).

    Attributes:
      sigma: bit precision of a feature element.
      B: L2 (HBM) bandwidth, bits/iteration.
      Bn: destination-vertex rows per adjacency block.
      Bk: source-vertex columns per adjacency block.
      sigma_adj: bit precision of one adjacency-block element (block-dense
          storage keeps explicit zeros, so topology traffic is dense).
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    Bn: ParamArray = 256
    Bk: ParamArray = 256
    sigma_adj: ParamArray = 4

    def replace(self, **kw: ParamArray) -> "TiledSpMMHardwareParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class AWBGCNHardwareParams:
    """AWB-GCN-style column-balanced dataflow (this repo's extension).

    AWB-GCN (Geng et al., MICRO 2020) performs column-wise-product SpMM on
    M PEs with an autotuning workload balancer; partial output columns are
    accumulated on-chip and a fraction ``rho`` of partial results is rerouted
    between PEs per autotuning round (DESIGN.md §7).

    Attributes:
      sigma: bit precision.
      B: L2 memory bandwidth, bits/iteration.
      M: number of PEs (AWB-GCN's published design point is 4096).
      eta: workload-balance efficiency achieved by the autotuner,
          0 < eta <= 1 (fraction of peak PE utilization).
      rho: fraction of partial results rerouted by the balancer.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    M: ParamArray = 4096
    eta: ParamArray = 0.85
    rho: ParamArray = 0.1

    def replace(self, **kw: ParamArray) -> "AWBGCNHardwareParams":
        return dataclasses.replace(self, **kw)


def paper_default_graph(
    K: ParamArray = 1024,
    *,
    N: ParamArray = 30,
    T: ParamArray = 5,
    edge_factor: float = 10.0,
    high_degree_fraction: float = 0.1,
) -> GraphTileParams:
    """Paper defaults (Sec. IV): N=30, T=5, P = 10 * K.

    ``L`` (high-degree vertices) has no published default; we model the
    degree-aware cache as serving 10% of the tile's vertices.
    """
    K_arr = _f64(K)
    return GraphTileParams(
        N=_f64(N),
        T=_f64(T),
        K=K_arr,
        L=np.floor(K_arr * high_degree_fraction),
        P=K_arr * edge_factor,
    )


#: Section IV default operating point: N=30, T=5, B=1000, sigma=4, P=10K.
PAPER_DEFAULT_GRAPH = paper_default_graph()
PAPER_DEFAULT_ENGN = EnGNHardwareParams()
PAPER_DEFAULT_HYGCN = HyGCNHardwareParams()


# Table II, left column: the graph tile, over the ROADMAP item-1 envelope
# (10^9-edge / 10^7-vertex graphs; feature widths up to 1024 elements).
declare_units(GraphTileParams, {
    "N": FieldUnit("elements", 1, 1024, "input feature-vector size"),
    "T": FieldUnit("elements", 1, 1024, "output feature-vector size"),
    "K": FieldUnit("vertices", 1, 1e7, "vertices in the tile"),
    "L": FieldUnit("vertices", 0, 1e7, "high-degree vertices in the tile"),
    "P": FieldUnit("edges", 0, 1e9, "edges in the tile"),
})

# Table II, right column (EnGN).
declare_units(EnGNHardwareParams, {
    "sigma": FieldUnit("bits", doc="precision of one feature element"),
    "B": FieldUnit("bits/iter", doc="L2 bank bandwidth"),
    "B_star": FieldUnit("bits/iter", doc="dedicated L2* cache bandwidth"),
    "M": FieldUnit("PEs", doc="PE-array rows"),
    "M_prime": FieldUnit("PEs", doc="PE-array columns"),
})

# Table II, right column (HyGCN).
declare_units(HyGCNHardwareParams, {
    "sigma": FieldUnit("bits", doc="precision of one feature element"),
    "B": FieldUnit("bits/iter", doc="L2 memory bandwidth"),
    "Ma": FieldUnit("PEs", doc="aggregation-engine SIMD cores"),
    "Mc": FieldUnit("PEs", doc="combination-engine systolic PEs"),
    "gamma": FieldUnit("dimensionless", doc="systolic weight-reuse factor"),
    "Ps_ratio": FieldUnit("dimensionless",
                          doc="edges surviving window sliding, / P"),
})

# This repo's extensions (DESIGN.md §7).
declare_units(TiledSpMMHardwareParams, {
    "sigma": FieldUnit("bits", doc="precision of one feature element"),
    "B": FieldUnit("bits/iter", doc="HBM bandwidth"),
    "Bn": FieldUnit("vertices", doc="destination rows per adjacency block"),
    "Bk": FieldUnit("vertices", doc="source columns per adjacency block"),
    "sigma_adj": FieldUnit("bits", doc="precision of one adjacency element"),
})

declare_units(AWBGCNHardwareParams, {
    "sigma": FieldUnit("bits", doc="precision of one feature element"),
    "B": FieldUnit("bits/iter", doc="L2 memory bandwidth"),
    "M": FieldUnit("PEs", doc="column-product PEs"),
    "eta": FieldUnit("dimensionless", doc="autotuned balance efficiency"),
    "rho": FieldUnit("dimensionless", doc="rerouted partial-result fraction"),
})


# ---------------------------------------------------------------------------
# Composition-layer parameter records (DESIGN.md §17): the typed-graph /
# minibatch closed forms of repro.core.compose.COMPOSITION_FORMS are traced
# over these, so the relation axis is audited with the same unit algebra,
# provenance tracking, and 2^53 interval envelope as the Table III/IV terms.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RelationalScheduleParams:
    """Per-tile schedule quantities of the typed / episode evaluations.

    Attributes:
      R: number of edge relations (types) in the typed graph; 1 for a
         homogeneous sampled-minibatch episode.
      H: unique remote (halo / gathered non-seed) source vertices of one
         tile or episode — the exact deduplicated count the trace measures.
      K: vertices resident in the tile (partition geometry, shared across
         relations).
      W: per-vertex feature elements moved per halo/hand-off vertex (the
         summed interior widths, ``halo_feature_elems``).
    """

    R: ParamArray
    H: ParamArray
    K: ParamArray
    W: ParamArray

    def replace(self, **kw: ParamArray) -> "RelationalScheduleParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class CompositionHardwareParams:
    """Architecture-independent hardware knobs of the composition terms.

    Every registered dataflow shares these two Table II symbols; the
    composition layer charges its halo / hand-off / gather terms with them
    regardless of which inner dataflow runs the tile.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000

    def replace(self, **kw: ParamArray) -> "CompositionHardwareParams":
        return dataclasses.replace(self, **kw)


# Relation counts span the tuner's supported range; halo / vertex counts
# share the ROADMAP item-1 vertex envelope (a tile's unique remote sources
# are at most V); widths share the feature-element envelope.
declare_units(RelationalScheduleParams, {
    "R": FieldUnit("relations", 1, 64, "edge relations in the typed graph"),
    "H": FieldUnit("vertices", 0, 1e7,
                   "unique remote / gathered source vertices per tile"),
    "K": FieldUnit("vertices", 1, 1e7, "vertices resident in the tile"),
    "W": FieldUnit("elements", 1, 1024,
                   "halo feature elements moved per vertex"),
})

declare_units(CompositionHardwareParams, {
    "sigma": FieldUnit("bits", doc="precision of one feature element"),
    "B": FieldUnit("bits/iter", doc="L2 memory bandwidth"),
})
