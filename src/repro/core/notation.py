"""Notation of the paper (Table II), as typed parameter records.

Every quantity is kept in the paper's units:

* feature-vector sizes ``N`` (input) and ``T`` (output) are *element counts*,
* ``sigma`` is the bit precision of one element,
* ``B`` is the L2 memory bandwidth in **bits per iteration** (the paper's
  iteration-granular bandwidth model),
* PE counts ``M``/``M'`` (EnGN array) and ``Ma``/``Mc`` (HyGCN engines) are
  numbers of processing elements.

All records are plain dataclasses of scalars *or* numpy arrays — the closed
forms in :mod:`repro.core.engn` / :mod:`repro.core.hygcn` broadcast, so a sweep
is expressed by passing an array for the swept field (see
:mod:`repro.core.sweep`).  Exact integer-valued float64 math is used throughout
(ceil-of-ratio terms must not suffer float32 rounding at K ~ 10^6).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Union

import numpy as np

ParamArray = Union[int, float, np.ndarray]

__all__ = [
    "ParamArray",
    "GraphTileParams",
    "EnGNHardwareParams",
    "HyGCNHardwareParams",
    "TiledSpMMHardwareParams",
    "AWBGCNHardwareParams",
    "PAPER_DEFAULT_GRAPH",
    "PAPER_DEFAULT_ENGN",
    "PAPER_DEFAULT_HYGCN",
    "paper_default_graph",
]


def _f64(x: ParamArray) -> np.ndarray:
    """Promote a parameter to float64 (exact for all integer magnitudes used)."""
    return np.asarray(x, dtype=np.float64)


@dataclass(frozen=True)
class GraphTileParams:
    """Input-graph parameters of a single tile (Table II, left column).

    Attributes:
      N: size of the input feature vector (elements).
      T: size of the output feature vector (elements).
      K: number of vertices in the tile.
      L: number of high-degree vertices in the tile (served by EnGN's
         dedicated L2* vertex cache).  The paper gives no default; we follow
         its "highly-connected vertices" narrative with L = K/10 unless
         overridden (see :func:`paper_default_graph`).
      P: number of edges in the tile.
    """

    N: ParamArray
    T: ParamArray
    K: ParamArray
    L: ParamArray
    P: ParamArray

    def replace(self, **kw: ParamArray) -> "GraphTileParams":
        return dataclasses.replace(self, **kw)

    def astuple_f64(self) -> tuple[np.ndarray, ...]:
        return tuple(_f64(v) for v in (self.N, self.T, self.K, self.L, self.P))


@dataclass(frozen=True)
class EnGNHardwareParams:
    """EnGN architecture parameters (Table II, right column).

    Attributes:
      sigma: bit precision of a feature element.
      B: L2 memory-bank bandwidth, bits/iteration.
      B_star: dedicated high-degree vertex-cache (L2*) bandwidth,
        bits/iteration.  Not given a default in the paper; defaults to ``B``.
      M: PE-array rows (vertices processed concurrently).
      M_prime: PE-array columns. EnGN default array is 128 x 16.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    B_star: ParamArray | None = None
    M: ParamArray = 128
    M_prime: ParamArray = 16

    @property
    def b_star(self) -> np.ndarray:
        return _f64(self.B if self.B_star is None else self.B_star)

    def replace(self, **kw: ParamArray) -> "EnGNHardwareParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class HyGCNHardwareParams:
    """HyGCN architecture parameters (Table II, right column).

    Attributes:
      sigma: bit precision.
      B: L2 memory bandwidth, bits/iteration.
      Ma: aggregation-engine PEs (32 SIMD cores, each covering up to 8
          feature components per step — the ``Ma * 8`` term in Table IV).
      Mc: combination-engine PEs (systolic array, 8 x 4 x 128 = 4096).
      gamma: systolic-array weight-reuse factor, 0 <= gamma < 1.
      Ps_ratio: edges remaining after HyGCN's window sliding, as a fraction
          of P.  The paper sets P_s ~ P, i.e. ratio 1.0.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    Ma: ParamArray = 32
    Mc: ParamArray = 8 * 4 * 128
    gamma: ParamArray = 0.5
    Ps_ratio: ParamArray = 1.0

    def Ps(self, P: ParamArray) -> np.ndarray:
        return _f64(P) * _f64(self.Ps_ratio)

    def replace(self, **kw: ParamArray) -> "HyGCNHardwareParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TiledSpMMHardwareParams:
    """Generic tiled block-dense SpMM accelerator (this repo's extension).

    The TPU/Pallas analogue of the paper's dataflows: the adjacency is tiled
    into (Bn x Bk) dense blocks and aggregation+combination are fused on one
    matrix unit, so no inter-phase buffer term exists (DESIGN.md §3/§7).
    ``Bn``/``Bk`` mirror ``DEFAULT_BLOCK_N``/``DEFAULT_BLOCK_K`` of
    :mod:`repro.kernels.edge_aggregate` — keep them in sync (asserted in
    tests when jax is importable).

    Attributes:
      sigma: bit precision of a feature element.
      B: L2 (HBM) bandwidth, bits/iteration.
      Bn: destination-vertex rows per adjacency block.
      Bk: source-vertex columns per adjacency block.
      sigma_adj: bit precision of one adjacency-block element (block-dense
          storage keeps explicit zeros, so topology traffic is dense).
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    Bn: ParamArray = 256
    Bk: ParamArray = 256
    sigma_adj: ParamArray = 4

    def replace(self, **kw: ParamArray) -> "TiledSpMMHardwareParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class AWBGCNHardwareParams:
    """AWB-GCN-style column-balanced dataflow (this repo's extension).

    AWB-GCN (Geng et al., MICRO 2020) performs column-wise-product SpMM on
    M PEs with an autotuning workload balancer; partial output columns are
    accumulated on-chip and a fraction ``rho`` of partial results is rerouted
    between PEs per autotuning round (DESIGN.md §7).

    Attributes:
      sigma: bit precision.
      B: L2 memory bandwidth, bits/iteration.
      M: number of PEs (AWB-GCN's published design point is 4096).
      eta: workload-balance efficiency achieved by the autotuner,
          0 < eta <= 1 (fraction of peak PE utilization).
      rho: fraction of partial results rerouted by the balancer.
    """

    sigma: ParamArray = 4
    B: ParamArray = 1000
    M: ParamArray = 4096
    eta: ParamArray = 0.85
    rho: ParamArray = 0.1

    def replace(self, **kw: ParamArray) -> "AWBGCNHardwareParams":
        return dataclasses.replace(self, **kw)


def paper_default_graph(
    K: ParamArray = 1024,
    *,
    N: ParamArray = 30,
    T: ParamArray = 5,
    edge_factor: float = 10.0,
    high_degree_fraction: float = 0.1,
) -> GraphTileParams:
    """Paper defaults (Sec. IV): N=30, T=5, P = 10 * K.

    ``L`` (high-degree vertices) has no published default; we model the
    degree-aware cache as serving 10% of the tile's vertices.
    """
    K_arr = _f64(K)
    return GraphTileParams(
        N=_f64(N),
        T=_f64(T),
        K=K_arr,
        L=np.floor(K_arr * high_degree_fraction),
        P=K_arr * edge_factor,
    )


#: Section IV default operating point: N=30, T=5, B=1000, sigma=4, P=10K.
PAPER_DEFAULT_GRAPH = paper_default_graph()
PAPER_DEFAULT_ENGN = EnGNHardwareParams()
PAPER_DEFAULT_HYGCN = HyGCNHardwareParams()
