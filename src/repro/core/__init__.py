"""Core contribution of the paper: analytical data-movement models.

Public surface:

* :mod:`repro.core.dataflow` — the declarative layer: an accelerator is a
  :class:`~repro.core.dataflow.DataflowSpec` (ordered movement-level closed
  forms) evaluated by one shared engine.
* :mod:`repro.core.registry` — resolve any registered dataflow by name:
  ``engn`` / ``hygcn`` (Tables III/IV), ``spmm_tiled`` (fused block-dense
  Pallas-kernel analogue), ``awb_gcn`` (column-balanced dataflow).
* :mod:`repro.core.compose` — composition layer: ``MultiLayerModel`` (L
  chained GNN layers with residency policy) and ``TiledGraphModel`` (full
  graphs over a tile schedule with halo reloads).
* :mod:`repro.core.trace` — trace-driven graph backend: exact edge-list
  tile schedules and unique-remote-source halo counts replacing the
  uniform-tile approximation (DESIGN.md §12).
* :mod:`repro.core.sweep` — Figures 3-7 sweep engine plus the stacked
  all-accelerator sweep.
* :mod:`repro.core.tpu_model` — the methodology adapted to a TPU v5e pod
  (three-term roofline + per-strategy analytical collective models).
* :mod:`repro.core.validation` — analytical-vs-compiled-HLO validation and
  seed golden totals for the registry-evaluated models.
* :mod:`repro.core.conformance` — measured-vs-modeled conformance: pins
  every dataflow with a runnable kernel analogue to byte measurements of
  the compiled Pallas/XLA programs (DESIGN.md §10).

The declarative query surface over all of the above lives one package up:
:mod:`repro.api` (DESIGN.md §11) — serializable ``Scenario`` objects and
a batch planner that evaluates any (dataflow x workload x graph x
hardware x composition) cross-product in one broadcast call per dataflow.
"""

from . import registry
from .awb_gcn import AWBGCNModel, AWB_GCN_SPEC
from .compose import (FullGraphParams, MultiLayerModel, RESIDENCY_POLICIES,
                      TiledGraphModel, tile_working_set_bits)
from .conformance import (ConformanceRecord, OperatingPoint,
                          default_operating_points, run_conformance,
                          summarize_records)
from .dataflow import DataflowSpec, MovementSpec, SpecModel, MOVEMENT_ROLES
from .engn import ENGN_SPEC, EnGNModel
from .hygcn import HYGCN_SPEC, HyGCNModel
from .notation import (AWBGCNHardwareParams, EnGNHardwareParams,
                       GraphTileParams, HyGCNHardwareParams,
                       PAPER_DEFAULT_ENGN, PAPER_DEFAULT_GRAPH,
                       PAPER_DEFAULT_HYGCN, TiledSpMMHardwareParams,
                       paper_default_graph)
from .spmm_tiled import SPMM_TILED_SPEC, TiledSpMMModel
from .trace import (GraphTrace, TraceSchedule, clear_trace_cache,
                    register_trace_dataset, reset_trace_stats,
                    resolve_trace_dataset, trace_cache_info,
                    trace_dataset_names)
from .tune import (InfeasibleBudgetError, TunePoint, TuneResult,
                   normalize_optimize, tune_scenario)
from .spmm_unfused import SPMM_UNFUSED_SPEC, UnfusedSpMMModel
from .terms import (AcceleratorModel, L1_CLASSES, L2_CLASSES, CACHE_CLASSES,
                    ModelOutput, MovementTerm, tabulate)

__all__ = [
    # declarative layer + registry
    "DataflowSpec",
    "MovementSpec",
    "SpecModel",
    "MOVEMENT_ROLES",
    "registry",
    # models / specs
    "EnGNModel",
    "HyGCNModel",
    "TiledSpMMModel",
    "UnfusedSpMMModel",
    "AWBGCNModel",
    "ENGN_SPEC",
    "HYGCN_SPEC",
    "SPMM_TILED_SPEC",
    "SPMM_UNFUSED_SPEC",
    "AWB_GCN_SPEC",
    # conformance
    "ConformanceRecord",
    "OperatingPoint",
    "default_operating_points",
    "run_conformance",
    "summarize_records",
    # composition
    "MultiLayerModel",
    "TiledGraphModel",
    "FullGraphParams",
    "RESIDENCY_POLICIES",
    "tile_working_set_bits",
    # trace backend (exact edge-list schedules, DESIGN.md §12)
    "GraphTrace",
    "TraceSchedule",
    "register_trace_dataset",
    "resolve_trace_dataset",
    "trace_dataset_names",
    "clear_trace_cache",
    "reset_trace_stats",
    "trace_cache_info",
    # design-space auto-tuner (DESIGN.md §15)
    "InfeasibleBudgetError",
    "TunePoint",
    "TuneResult",
    "normalize_optimize",
    "tune_scenario",
    # notation
    "GraphTileParams",
    "EnGNHardwareParams",
    "HyGCNHardwareParams",
    "TiledSpMMHardwareParams",
    "AWBGCNHardwareParams",
    "paper_default_graph",
    "PAPER_DEFAULT_GRAPH",
    "PAPER_DEFAULT_ENGN",
    "PAPER_DEFAULT_HYGCN",
    # term algebra
    "AcceleratorModel",
    "ModelOutput",
    "MovementTerm",
    "tabulate",
    "L1_CLASSES",
    "L2_CLASSES",
    "CACHE_CLASSES",
]
