"""Core contribution of the paper: analytical data-movement models.

Public surface:

* :class:`~repro.core.engn.EnGNModel` / :class:`~repro.core.hygcn.HyGCNModel`
  — Tables III/IV as closed-form, broadcasting models.
* :mod:`repro.core.sweep` — Figures 3-7 sweep engine.
* :mod:`repro.core.tpu_model` — the methodology adapted to a TPU v5e pod
  (three-term roofline + per-strategy analytical collective models).
* :mod:`repro.core.validation` — analytical-vs-compiled-HLO validation.
"""

from .engn import EnGNModel
from .hygcn import HyGCNModel
from .notation import (EnGNHardwareParams, GraphTileParams,
                       HyGCNHardwareParams, PAPER_DEFAULT_ENGN,
                       PAPER_DEFAULT_GRAPH, PAPER_DEFAULT_HYGCN,
                       paper_default_graph)
from .terms import (AcceleratorModel, L1_CLASSES, L2_CLASSES, CACHE_CLASSES,
                    ModelOutput, MovementTerm, tabulate)

__all__ = [
    "EnGNModel",
    "HyGCNModel",
    "GraphTileParams",
    "EnGNHardwareParams",
    "HyGCNHardwareParams",
    "paper_default_graph",
    "PAPER_DEFAULT_GRAPH",
    "PAPER_DEFAULT_ENGN",
    "PAPER_DEFAULT_HYGCN",
    "AcceleratorModel",
    "ModelOutput",
    "MovementTerm",
    "tabulate",
    "L1_CLASSES",
    "L2_CLASSES",
    "CACHE_CLASSES",
]
