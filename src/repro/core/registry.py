"""Accelerator registry: resolve any registered dataflow by name.

The paper's goal is *comparative* analysis of vastly different GNN
accelerators; the registry is the single point where the sweep engine
(:mod:`repro.core.sweep`), validation (:mod:`repro.core.validation`),
benchmarks, and examples look accelerators up.  Adding an accelerator is
now: write a :class:`~repro.core.dataflow.DataflowSpec` and call
:func:`register` — no sweep/benchmark/example code changes.

Built-in entries: ``engn`` and ``hygcn`` (Tables III/IV of the paper),
``spmm_tiled`` (the repo's fused block-dense Pallas-kernel analogue),
``spmm_unfused`` (the two-pass HyGCN inter-phase analogue), and
``awb_gcn`` (column-balanced dataflow, MICRO 2020) — see DESIGN.md §4/§7.
The two spmm dataflows declare runnable kernel analogues
(``DataflowSpec.runnable``), which the conformance subsystem
(:mod:`repro.core.conformance`, DESIGN.md §10) pins to measured bytes.

Every registered spec is also subject to the static model auditor
(:mod:`repro.analysis`, DESIGN.md §16): ``python -m repro.analysis
--strict`` symbolically re-derives units and symbol provenance for each
movement form.  Audits key on the spec *value* (specs are frozen
dataclasses), so swapping a spec in — including via
:func:`temporarily_registered` — always triggers a fresh audit.
"""

from __future__ import annotations

from contextlib import contextmanager

from .awb_gcn import AWB_GCN_SPEC
from .dataflow import DataflowSpec, SpecModel
from .engn import ENGN_SPEC
from .hygcn import HYGCN_SPEC
from .spmm_tiled import SPMM_TILED_SPEC
from .spmm_unfused import SPMM_UNFUSED_SPEC
from .terms import ModelOutput

__all__ = ["register", "unregister", "temporarily_registered", "get",
           "names", "specs", "model", "evaluate", "runnable_names"]

_REGISTRY: dict[str, DataflowSpec] = {}


def register(spec: DataflowSpec, *, overwrite: bool = False) -> DataflowSpec:
    """Register a dataflow spec under its own name; returns it for chaining."""
    if not isinstance(spec, DataflowSpec):
        raise TypeError(f"expected DataflowSpec, got {type(spec).__name__}")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"accelerator {spec.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[spec.name] = spec
    return spec


def unregister(name: str) -> DataflowSpec:
    """Remove and return a registered spec; KeyError if absent."""
    try:
        return _REGISTRY.pop(name)
    except KeyError:
        raise KeyError(f"cannot unregister unknown accelerator {name!r}; "
                       f"registered: {names()}") from None


@contextmanager
def temporarily_registered(*specs: DataflowSpec, overwrite: bool = False):
    """Register specs for the duration of a ``with`` block, then restore.

    Lets tests and the scenario planner evaluate throwaway dataflows by
    name without leaking global registry state across the suite.  Any spec
    shadowed via ``overwrite=True`` is reinstated on exit; specs newly
    added are removed even if the body already unregistered them.
    """
    shadowed: dict[str, DataflowSpec] = {}
    added: list[str] = []
    try:
        for spec in specs:
            # Record only the FIRST pre-existing occupant of a name (later
            # same-name specs in this call are temporaries, not state to
            # restore), and register inside the try so a failure mid-way
            # still rolls back the specs already added.
            if spec.name not in shadowed and spec.name not in added:
                if spec.name in _REGISTRY:
                    if not overwrite:
                        raise ValueError(
                            f"accelerator {spec.name!r} already registered "
                            "(pass overwrite=True to shadow)")
                    shadowed[spec.name] = _REGISTRY[spec.name]
                else:
                    added.append(spec.name)
            register(spec, overwrite=overwrite)
        yield tuple(specs)
    finally:
        for name in added:
            _REGISTRY.pop(name, None)
        _REGISTRY.update(shadowed)


def get(name: str) -> DataflowSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown accelerator {name!r}; registered: {names()}") from None


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def specs() -> tuple[DataflowSpec, ...]:
    return tuple(_REGISTRY.values())


def model(name: str) -> SpecModel:
    """A class-API model wrapping the named spec."""
    return SpecModel(get(name))


def evaluate(name: str, graph, hw=None) -> ModelOutput:
    """Resolve + evaluate in one call (the common sweep-engine path)."""
    return get(name).evaluate(graph, hw)


def runnable_names() -> tuple[str, ...]:
    """Dataflows declaring a compilable kernel analogue (conformance)."""
    return tuple(name for name, spec in _REGISTRY.items() if spec.has_runnable)


for _spec in (ENGN_SPEC, HYGCN_SPEC, SPMM_TILED_SPEC, SPMM_UNFUSED_SPEC,
              AWB_GCN_SPEC):
    register(_spec)
del _spec
