"""Segment-reduce engine for trace schedules: jitted JAX + a Pallas kernel.

The amortized trace partitioner (DESIGN.md §13) reduces every
per-capacity schedule quantity to *segmented counts over one shared
sorted-edge factorization*: the unique ``(sender, receiver)`` pairs in
sender-major order plus their edge multiplicities.  ``dst_tile =
receiver // K`` is monotone within each sender segment, so the
deduplicated ``(dst_tile, source)`` pairs of any stride K are runs
delimited by a boundary flag, and the halo / cut-edge totals are
histograms of those flags (and multiplicity-weighted flags) over
destination tiles.

This module is the accelerator-resident version of that pass:

* :func:`schedule_counts` — the jitted jnp path
  (``jax.ops.segment_sum`` over int32 flags; bit-identical integers to
  the numpy engine, pinned in tests).  The tile axis is padded to a
  static ``n_tiles_pad`` so a whole capacity sweep shares ONE
  compilation (``GraphTrace.schedules(caps, engine="jax")`` passes the
  sweep's max tile count).
* :func:`tile_histogram` — the Pallas segment-reduce kernel: grid over
  edge blocks, each block one-hot-expands its tile ids against a
  broadcasted iota and accumulates ``weights @ onehot`` on the MXU into
  a VMEM-resident ``(1, n_tiles)`` output (the same masked-matmul trick
  the block-dense SpMM kernels use — the MXU eats the zeros).  Runs
  under ``interpret=True`` on CPU in CI; float32 accumulation is exact
  for integer counts below 2^24 per tile (asserted by the wrapper).
* :func:`schedule_counts_pallas` — the halo/multiplicity counts routed
  through the Pallas kernel, numpy-parity-pinned in the test battery.

Like every kernel in this package, the module is an *optional* fast
path: `repro.core.trace` imports it lazily, and the numpy engine remains
the default and the semantic reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "schedule_counts",
    "schedule_counts_pallas",
    "tile_histogram",
    "boundary_flags",
]

#: Edges per Pallas grid step (one-hot block height).
DEFAULT_BLOCK_EDGES = 4096
#: float32 accumulation holds integers exactly below this.
_F32_EXACT = 1 << 24


def boundary_flags(new_src: jax.Array, tile: jax.Array) -> jax.Array:
    """True where a new ``(source, dst_tile)`` run starts in the unique
    sender-major pair list (``new_src`` is the precomputed new-sender
    mask; the first entry always starts a run)."""
    if tile.shape[0] == 0:
        return jnp.zeros((0,), dtype=bool)
    head = jnp.ones((1,), dtype=bool)
    return new_src | jnp.concatenate([head, tile[1:] != tile[:-1]])


@functools.partial(jax.jit, static_argnums=(5,))
def _schedule_counts_jnp(u_snd, u_rcv, u_new_src, mult, K, n_tiles_pad):
    tile = u_rcv // K
    remote = (u_snd // K) != tile
    new_pair = boundary_flags(u_new_src, tile)
    halo = jax.ops.segment_sum((new_pair & remote).astype(jnp.int32),
                               tile, num_segments=n_tiles_pad)
    cut = jax.ops.segment_sum(jnp.where(remote, mult, 0),
                              tile, num_segments=n_tiles_pad)
    return halo, cut


def schedule_counts(u_snd, u_rcv, u_new_src, mult, K, n_tiles_pad: int):
    """(halo_counts, remote_edge_counts) over a padded tile axis, jitted.

    Operands are the shared factorization of ``GraphTrace``: unique
    ``(sender, receiver)`` pairs in sender-major order, the new-sender
    mask, and the per-pair edge multiplicities.  ``K`` is the (dynamic)
    tile stride, ``n_tiles_pad`` the static padded tile count — tiles
    beyond ``ceil(V/K)`` come back 0, and a whole capacity sweep padded
    to its max tile count shares one compilation.  Integer-exact (int32
    segment sums; counts are bounded by E).
    """
    u_snd = jnp.asarray(u_snd)
    u_rcv = jnp.asarray(u_rcv)
    return _schedule_counts_jnp(u_snd, u_rcv, jnp.asarray(u_new_src),
                                jnp.asarray(mult, jnp.int32),
                                jnp.asarray(K, u_rcv.dtype),
                                int(n_tiles_pad))


# ---------------------------------------------------------------------------
# The Pallas kernel: blocked one-hot histogram (segment-reduce by matmul).
# ---------------------------------------------------------------------------
def _hist_kernel(ids_ref, w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                       # (1, B) int32 tile ids
    w = w_ref[...]                           # (1, B) float32 weights
    block, n = ids.shape[1], out_ref.shape[1]
    # One-hot expansion against a broadcasted iota: row e selects the
    # destination-tile column of edge e (padding ids select nothing).
    onehot = (ids[0, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (block, n), 1)
              ).astype(jnp.float32)
    # (1, B) @ (B, n): the whole block's histogram in one MXU pass.
    out_ref[...] += jnp.dot(w, onehot, preferred_element_type=jnp.float32)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def tile_histogram(ids, weights, n_tiles: int, *,
                   block_edges: int = DEFAULT_BLOCK_EDGES,
                   interpret: bool = True) -> jax.Array:
    """``bincount(ids, weights, minlength=n_tiles)`` as a Pallas kernel.

    ``ids`` int tile ids in ``[0, n_tiles)``, ``weights`` non-negative
    integer-valued counts (float32-able); both 1-D of equal length.
    Accumulates in float32 — exact for integer totals below 2^24, so the
    guard bounds the *accumulated weight* (total count), which also
    bounds every per-tile total and every individual weight.
    """
    ids = jnp.asarray(ids, dtype=jnp.int32)
    weights = jnp.asarray(weights, dtype=jnp.float32)
    if ids.ndim != 1 or ids.shape != weights.shape:
        raise ValueError(f"ids/weights must be equal-length 1-D arrays, got "
                         f"{ids.shape} and {weights.shape}")
    # float64 on the host: the guard itself must not round.
    total = float(np.asarray(weights, dtype=np.float64).sum())
    if total >= _F32_EXACT:
        raise ValueError(
            f"tile_histogram accumulates in float32 (integer-exact below "
            f"2^24 per tile); a total weight of {total:.4g} can overflow "
            "that — use the jitted segment_sum path (schedule_counts) at "
            "this scale")
    n_tiles = int(n_tiles)
    block = int(block_edges)
    e_pad = _round_up(max(int(ids.shape[0]), 1), block)
    n_pad = _round_up(max(n_tiles, 1), 128)
    # Pad ids with n_pad (matches no iota column) and weights with 0.
    ids2 = jnp.full((1, e_pad), n_pad, dtype=jnp.int32)
    ids2 = ids2.at[0, :ids.shape[0]].set(ids)
    w2 = jnp.zeros((1, e_pad), dtype=jnp.float32)
    w2 = w2.at[0, :weights.shape[0]].set(weights)
    out = pl.pallas_call(
        _hist_kernel,
        grid=(e_pad // block,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i)),
                  pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        interpret=interpret,
    )(ids2, w2)
    return out[0, :n_tiles]


def schedule_counts_pallas(u_snd, u_rcv, u_new_src, mult, K, n_tiles: int, *,
                           block_edges: int = DEFAULT_BLOCK_EDGES,
                           interpret: bool = True):
    """(halo_counts, remote_edge_counts) with the histograms on the
    Pallas kernel (float32; numpy-parity-pinned on CI sizes)."""
    u_snd = jnp.asarray(u_snd)
    u_rcv = jnp.asarray(u_rcv)
    K = jnp.asarray(K, u_rcv.dtype)
    tile = (u_rcv // K).astype(jnp.int32)
    remote = (u_snd // K).astype(jnp.int32) != tile
    new_pair = boundary_flags(jnp.asarray(u_new_src), tile)
    halo = tile_histogram(tile, (new_pair & remote).astype(jnp.float32),
                          n_tiles, block_edges=block_edges,
                          interpret=interpret)
    cut = tile_histogram(tile,
                         jnp.where(remote, jnp.asarray(mult, jnp.float32),
                                   0.0),
                         n_tiles, block_edges=block_edges,
                         interpret=interpret)
    return halo, cut
