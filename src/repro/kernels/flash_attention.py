"""Blocked online-softmax (flash) attention Pallas kernel.

Replaces the HLO-level chunked attention of
:mod:`repro.models.attention` on real TPU hardware: the (BQ, BK) score tile
never leaves VMEM, with running max / sum-exp accumulators carried across
KV blocks — the transformer-side analogue of keeping the paper's
inter-phase traffic on-chip.

Layout: inputs are flattened to (B*H, S, D) by ops.py; the grid is
(batch*heads, q blocks, kv blocks) with the kv dimension innermost so the
accumulators live across the inner loop.  Supports causal and
sliding-window masking (gemma2's local layers).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, n_kv_blocks: int, scale: float,
            causal: bool, window: Optional[int], softcap: Optional[float]):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0].astype(jnp.float32)                  # (BK, D)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)

    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask, scores, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
    p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    grid = (bh, s // block_q, s // block_k)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          n_kv_blocks=grid[2], scale=scale, causal=causal,
                          window=window, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
