"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU validation per the brief); on real TPU
hardware the launcher flips it to False.  ``flash_attention`` takes the
model-layout (B, S, H, D) tensors and handles the (B*H, S, D) flattening +
GQA head replication so :mod:`repro.models.attention` can swap it in
one-for-one.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .edge_aggregate import fused_aggregate_combine
from .edge_aggregate_unfused import aggregate_pass, combine_pass
from .embedding_bag import embedding_bag as _embedding_bag
from .flash_attention import flash_attention_bhsd


@partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def gnn_aggregate_combine(adjacency: jax.Array, x: jax.Array, w: jax.Array,
                          *, block_n: int = 256, block_k: int = 256,
                          interpret: bool = True) -> jax.Array:
    return fused_aggregate_combine(adjacency, x, w, block_n=block_n,
                                   block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def gnn_aggregate(adjacency: jax.Array, x: jax.Array, *,
                  block_n: int = 256, block_k: int = 256,
                  interpret: bool = True) -> jax.Array:
    """Unfused pass 1: Y_agg = A @ X (the aggregate materializes in HBM)."""
    return aggregate_pass(adjacency, x, block_n=block_n, block_k=block_k,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def gnn_combine(y_agg: jax.Array, w: jax.Array, *, block_n: int = 256,
                interpret: bool = True) -> jax.Array:
    """Unfused pass 2: Y = Y_agg @ W (reads the inter-phase buffer back).

    Jitted separately from :func:`gnn_aggregate` on purpose — the pair is
    the HyGCN inter-phase analogue, and fusing the passes into one program
    would let XLA elide exactly the traffic being modelled."""
    return combine_pass(y_agg, w, block_n=block_n, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q (B, S, H, D); k, v (B, S, Hk, D) with Hk | H (GQA)."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    rep = h // hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    to_bhsd = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    o = flash_attention_bhsd(to_bhsd(q), to_bhsd(k), to_bhsd(v),
                             causal=causal, window=window, softcap=softcap,
                             block_q=min(block_q, s), block_k=min(block_k, s),
                             interpret=interpret)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jax.Array, indices: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    return _embedding_bag(table, indices, interpret=interpret)
