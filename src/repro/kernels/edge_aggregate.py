"""Fused GNN aggregate+combine Pallas kernel (TPU-adapted from the paper).

The paper's two accelerators split a GNN layer into an *aggregation* stage
and a *combination* stage.  HyGCN pipelines them through an inter-phase
buffer whose write/read traffic (Table IV ``writeinterphase`` /
``readinterphase``) is, per Fig. 4, a dominant share of its off-chip data
movement.  EnGN avoids the buffer by running both stages on one PE array.

TPU adaptation (DESIGN.md §3):
* The gather/scatter aggregation becomes **block-dense SpMM**: the pipeline
  tiles the adjacency into (BN x BK) dense blocks (zeros where no edge —
  the MXU eats zeros at full rate, and real GNN accelerators for TPU-class
  hardware do exactly this), so aggregation is a masked matmul.
* Aggregate and combine are FUSED in one kernel: the aggregated tile lives
  in a VMEM accumulator and is immediately multiplied by the combine weight
  W — the inter-phase buffer collapses into registers.  The HBM traffic
  eliminated per (K-node, N-feature) tile is exactly the paper's
  ``K*N*sigma`` write + ``P_s*N*sigma`` read terms (the unfused two-pass
  baseline in :mod:`repro.kernels.edge_aggregate_unfused` pays them).

Grid: (num dst node blocks, num src node blocks).  For each dst block i the
kernel accumulates sum_j A[i,j] @ X[j] in VMEM and, on the last j, applies
the (F x T) combine weight and writes the (BN x T) output tile once.

Byte accounting (DESIGN.md §10): :func:`fused_grid_spec` is the single
source of the kernel's grid + block geometry — ``pallas_call`` consumes it
and :func:`fused_block_streams` re-exports the same index maps as
movement-level-named stream descriptors, so the conformance subsystem
(:mod:`repro.core.conformance`) measures the schedule the kernel actually
launches, not a transcription of it.

``emit(..., interpret=True)`` validates on CPU; ops.py wraps it jitted.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256   # dst nodes per tile (the paper's K)
DEFAULT_BLOCK_K = 256   # src nodes per tile


def _kernel(a_ref, x_ref, w_ref, out_ref, acc_ref, *, n_src_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Aggregation micro-step on the MXU: (BN, BK) @ (BK, F).
    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_src_blocks - 1)
    def _combine():
        # Combination stage fused in: no inter-phase buffer ever leaves VMEM.
        out_ref[...] = jnp.dot(acc_ref[...], w_ref[...],
                               preferred_element_type=jnp.float32
                               ).astype(out_ref.dtype)


def fused_grid_spec(n: int, f: int, t: int, block_n: int, block_k: int):
    """Grid + (block_shape, index_map) geometry of the fused kernel.

    Returns ``(grid, in_geoms, out_geom)`` with one ``(shape, index_map)``
    pair per operand in call order (A, X, W) and one for the output.  The
    same pairs construct the ``pallas_call`` BlockSpecs and the conformance
    stream descriptors — keep them in sync by construction.
    """
    assert n % block_n == 0 and n % block_k == 0, (n, block_n, block_k)
    grid = (n // block_n, n // block_k)
    in_geoms = (
        ((block_n, block_k), lambda i, j: (i, j)),   # A tile
        ((block_k, f), lambda i, j: (j, 0)),         # X tile
        ((f, t), lambda i, j: (0, 0)),               # W (resident)
    )
    out_geom = ((block_n, t), lambda i, j: (i, 0))
    return grid, in_geoms, out_geom


def fused_block_streams(n: int, f: int, t: int, *,
                        block_n: int = DEFAULT_BLOCK_N,
                        block_k: int = DEFAULT_BLOCK_K,
                        elem_bytes: float = 4.0) -> dict:
    """Movement-level-named HBM stream descriptors of the fused kernel.

    Keys match the ``spmm_tiled`` dataflow's off-chip movement levels; each
    value carries the block shape, the *actual* kernel index map, the
    element width, and the transfer direction — everything the conformance
    schedule trace needs (DESIGN.md §10).
    """
    grid, (a_g, x_g, w_g), out_g = fused_grid_spec(n, f, t, block_n, block_k)
    return {
        "grid": grid,
        "streams": {
            "loadadjblocks": {"block_shape": a_g[0], "index_map": a_g[1],
                              "elem_bytes": elem_bytes, "kind": "read"},
            "loadvertblocks": {"block_shape": x_g[0], "index_map": x_g[1],
                               "elem_bytes": elem_bytes, "kind": "read"},
            "loadweights": {"block_shape": w_g[0], "index_map": w_g[1],
                            "elem_bytes": elem_bytes, "kind": "read"},
            "writeout": {"block_shape": out_g[0], "index_map": out_g[1],
                         "elem_bytes": elem_bytes, "kind": "write"},
        },
    }


def fused_aggregate_combine(adjacency: jax.Array, x: jax.Array, w: jax.Array,
                            *, block_n: int = DEFAULT_BLOCK_N,
                            block_k: int = DEFAULT_BLOCK_K,
                            interpret: bool = True) -> jax.Array:
    """Y = (A @ X) @ W with A (N, N) block-dense, X (N, F), W (F, T).

    N must divide evenly into block_n/block_k tiles (the data pipeline pads
    graphs to these multiples, mirroring the paper's tiling preprocessing).
    """
    n, f = x.shape
    t = w.shape[1]
    assert adjacency.shape == (n, n), (adjacency.shape, n)
    assert w.shape[0] == f
    block_n = min(block_n, n)
    block_k = min(block_k, n)
    grid, in_geoms, out_geom = fused_grid_spec(n, f, t, block_n, block_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_src_blocks=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec(shape, imap) for shape, imap in in_geoms],
        out_specs=pl.BlockSpec(*out_geom),
        out_shape=jax.ShapeDtypeStruct((n, t), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, f), jnp.float32)],
        interpret=interpret,
    )(adjacency, x, w)
