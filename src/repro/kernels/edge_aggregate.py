"""Fused GNN aggregate+combine Pallas kernel (TPU-adapted from the paper).

The paper's two accelerators split a GNN layer into an *aggregation* stage
and a *combination* stage.  HyGCN pipelines them through an inter-phase
buffer whose write/read traffic (Table IV ``writeinterphase`` /
``readinterphase``) is, per Fig. 4, a dominant share of its off-chip data
movement.  EnGN avoids the buffer by running both stages on one PE array.

TPU adaptation (DESIGN.md §3):
* The gather/scatter aggregation becomes **block-dense SpMM**: the pipeline
  tiles the adjacency into (BN x BK) dense blocks (zeros where no edge —
  the MXU eats zeros at full rate, and real GNN accelerators for TPU-class
  hardware do exactly this), so aggregation is a masked matmul.
* Aggregate and combine are FUSED in one kernel: the aggregated tile lives
  in a VMEM accumulator and is immediately multiplied by the combine weight
  W — the inter-phase buffer collapses into registers.  The HBM traffic
  eliminated per (K-node, N-feature) tile is exactly the paper's
  ``K*N*sigma`` write + ``P_s*N*sigma`` read terms.

Grid: (num dst node blocks, num src node blocks).  For each dst block i the
kernel accumulates sum_j A[i,j] @ X[j] in VMEM and, on the last j, applies
the (F x T) combine weight and writes the (BN x T) output tile once.

``emit(..., interpret=True)`` validates on CPU; ops.py wraps it jitted.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256   # dst nodes per tile (the paper's K)
DEFAULT_BLOCK_K = 256   # src nodes per tile


def _kernel(a_ref, x_ref, w_ref, out_ref, acc_ref, *, n_src_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Aggregation micro-step on the MXU: (BN, BK) @ (BK, F).
    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_src_blocks - 1)
    def _combine():
        # Combination stage fused in: no inter-phase buffer ever leaves VMEM.
        out_ref[...] = jnp.dot(acc_ref[...], w_ref[...],
                               preferred_element_type=jnp.float32
                               ).astype(out_ref.dtype)


def fused_aggregate_combine(adjacency: jax.Array, x: jax.Array, w: jax.Array,
                            *, block_n: int = DEFAULT_BLOCK_N,
                            block_k: int = DEFAULT_BLOCK_K,
                            interpret: bool = True) -> jax.Array:
    """Y = (A @ X) @ W with A (N, N) block-dense, X (N, F), W (F, T).

    N must divide evenly into block_n/block_k tiles (the data pipeline pads
    graphs to these multiples, mirroring the paper's tiling preprocessing).
    """
    n, f = x.shape
    t = w.shape[1]
    assert adjacency.shape == (n, n), (adjacency.shape, n)
    assert w.shape[0] == f
    block_n = min(block_n, n)
    block_k = min(block_k, n)
    assert n % block_n == 0 and n % block_k == 0, (n, block_n, block_k)
    grid = (n // block_n, n // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_src_blocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),   # A tile
            pl.BlockSpec((block_k, f), lambda i, j: (j, 0)),         # X tile
            pl.BlockSpec((f, t), lambda i, j: (0, 0)),               # W
        ],
        out_specs=pl.BlockSpec((block_n, t), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, f), jnp.float32)],
        interpret=interpret,
    )(adjacency, x, w)
