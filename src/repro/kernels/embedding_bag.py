"""EmbeddingBag Pallas kernel: scalar-prefetched row gather + pooled sum.

JAX has no native EmbeddingBag; the jnp path is take + sum (ref.py).  On
TPU the gather is the hot path of DLRM, so here the bag indices are
*scalar-prefetched* — the BlockSpec index_map reads the index array to pick
which (1, D) table row block the DMA engine fetches next, turning the
random-access gather into a software-pipelined stream of row copies (the
TPU answer to the paper's ``loadvert`` streaming constraint min(B, M*sigma)).

Grid: (batch, bag); each inner step accumulates one row into the output
block (revisited across the bag dimension).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(out_ref.dtype)


def embedding_bag(table: jax.Array, indices: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """table (V, D) f32, indices (B, hot) int32 -> (B, D) summed bags."""
    v, d = table.shape
    b, hot = indices.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hot),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, h, idx_ref: (idx_ref[bi, h], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bi, h, idx_ref: (bi, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(indices, table)
