"""Unfused two-pass aggregate / combine Pallas kernels — HyGCN's analogue.

The counterpart of :mod:`repro.kernels.edge_aggregate`: the same block-dense
SpMM pipeline, but aggregation and combination run as two separately
compiled kernels with the aggregated (K x N) features materialized in HBM
between them — the TPU realization of HyGCN's inter-phase buffer (Table IV
``writeinterphase`` / ``readinterphase``).  Compiling the passes separately
is the point: the aggregate crosses the executable boundary, so its HBM
round-trip is measurable ground truth for the conformance subsystem
(:mod:`repro.core.conformance`), and the fused-minus-unfused measured delta
is exactly the paper's eliminated ``K*N*sigma + P_s*N*sigma`` terms.

Pass 1 — :func:`aggregate_pass`:  Y_agg = A @ X, grid (dst blocks, src
blocks), VMEM accumulator, aggregate tile written on the last src block.
Pass 2 — :func:`combine_pass`:    Y = Y_agg @ W, grid (dst blocks,).

Analytical counterpart: the registered ``spmm_unfused`` dataflow
(:mod:`repro.core.spmm_unfused`).  Like the fused kernel, each pass exposes
its grid + index-map geometry through a ``*_grid_spec`` /
``*_block_streams`` helper pair so conformance traces the launched
schedule, not a transcription (DESIGN.md §10).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .edge_aggregate import DEFAULT_BLOCK_K, DEFAULT_BLOCK_N


def _aggregate_kernel(a_ref, x_ref, out_ref, acc_ref, *, n_src_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_src_blocks - 1)
    def _flush():
        # The inter-phase spill HyGCN pays: the aggregate leaves the array.
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _combine_kernel(y_ref, w_ref, out_ref):
    out_ref[...] = jnp.dot(y_ref[...], w_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def aggregate_grid_spec(n: int, f: int, block_n: int, block_k: int):
    """Grid + (block_shape, index_map) geometry of the aggregation pass."""
    assert n % block_n == 0 and n % block_k == 0, (n, block_n, block_k)
    grid = (n // block_n, n // block_k)
    in_geoms = (
        ((block_n, block_k), lambda i, j: (i, j)),   # A tile
        ((block_k, f), lambda i, j: (j, 0)),         # X tile
    )
    out_geom = ((block_n, f), lambda i, j: (i, 0))   # aggregate spill
    return grid, in_geoms, out_geom


def combine_grid_spec(n: int, f: int, t: int, block_n: int):
    """Grid + (block_shape, index_map) geometry of the combination pass."""
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    in_geoms = (
        ((block_n, f), lambda i: (i, 0)),            # aggregate re-fetch
        ((f, t), lambda i: (0, 0)),                  # W (resident)
    )
    out_geom = ((block_n, t), lambda i: (i, 0))
    return grid, in_geoms, out_geom


def aggregate_block_streams(n: int, f: int, *,
                            block_n: int = DEFAULT_BLOCK_N,
                            block_k: int = DEFAULT_BLOCK_K,
                            elem_bytes: float = 4.0) -> dict:
    """Movement-level-named stream descriptors of the aggregation pass,
    keyed to the ``spmm_unfused`` dataflow (DESIGN.md §10)."""
    grid, (a_g, x_g), out_g = aggregate_grid_spec(n, f, block_n, block_k)
    return {
        "grid": grid,
        "streams": {
            "loadadjblocks": {"block_shape": a_g[0], "index_map": a_g[1],
                              "elem_bytes": elem_bytes, "kind": "read"},
            "loadvertblocks": {"block_shape": x_g[0], "index_map": x_g[1],
                               "elem_bytes": elem_bytes, "kind": "read"},
            "writeinterphase": {"block_shape": out_g[0], "index_map": out_g[1],
                                "elem_bytes": elem_bytes, "kind": "write"},
        },
    }


def combine_block_streams(n: int, f: int, t: int, *,
                          block_n: int = DEFAULT_BLOCK_N,
                          elem_bytes: float = 4.0) -> dict:
    """Movement-level-named stream descriptors of the combination pass."""
    grid, (y_g, w_g), out_g = combine_grid_spec(n, f, t, block_n)
    return {
        "grid": grid,
        "streams": {
            "readinterphase": {"block_shape": y_g[0], "index_map": y_g[1],
                               "elem_bytes": elem_bytes, "kind": "read"},
            "loadweights": {"block_shape": w_g[0], "index_map": w_g[1],
                            "elem_bytes": elem_bytes, "kind": "read"},
            "writeout": {"block_shape": out_g[0], "index_map": out_g[1],
                         "elem_bytes": elem_bytes, "kind": "write"},
        },
    }


def aggregate_pass(adjacency: jax.Array, x: jax.Array, *,
                   block_n: int = DEFAULT_BLOCK_N,
                   block_k: int = DEFAULT_BLOCK_K,
                   interpret: bool = True) -> jax.Array:
    """Y_agg = A @ X with A (N, N) block-dense, X (N, F)."""
    n, f = x.shape
    assert adjacency.shape == (n, n), (adjacency.shape, n)
    block_n = min(block_n, n)
    block_k = min(block_k, n)
    grid, in_geoms, out_geom = aggregate_grid_spec(n, f, block_n, block_k)

    return pl.pallas_call(
        functools.partial(_aggregate_kernel, n_src_blocks=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec(shape, imap) for shape, imap in in_geoms],
        out_specs=pl.BlockSpec(*out_geom),
        out_shape=jax.ShapeDtypeStruct((n, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, f), jnp.float32)],
        interpret=interpret,
    )(adjacency, x)


def combine_pass(y_agg: jax.Array, w: jax.Array, *,
                 block_n: int = DEFAULT_BLOCK_N,
                 interpret: bool = True) -> jax.Array:
    """Y = Y_agg @ W with Y_agg (N, F), W (F, T)."""
    n, f = y_agg.shape
    t = w.shape[1]
    assert w.shape[0] == f
    block_n = min(block_n, n)
    grid, in_geoms, out_geom = combine_grid_spec(n, f, t, block_n)

    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(shape, imap) for shape, imap in in_geoms],
        out_specs=pl.BlockSpec(*out_geom),
        out_shape=jax.ShapeDtypeStruct((n, t), y_agg.dtype),
        interpret=interpret,
    )(y_agg, w)


def unfused_aggregate_combine(adjacency: jax.Array, x: jax.Array,
                              w: jax.Array, *,
                              block_n: int = DEFAULT_BLOCK_N,
                              block_k: int = DEFAULT_BLOCK_K,
                              interpret: bool = True) -> jax.Array:
    """Two-pass Y = (A @ X) @ W — numerically the fused kernel's oracle
    twin; the aggregate round-trips through memory between the passes."""
    y_agg = aggregate_pass(adjacency, x, block_n=block_n, block_k=block_k,
                           interpret=interpret)
    return combine_pass(y_agg, w, block_n=block_n, interpret=interpret)
