"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_aggregate_combine_ref(adjacency: jax.Array, x: jax.Array,
                                w: jax.Array) -> jax.Array:
    """Y = (A @ X) @ W in fp32 accumulation."""
    agg = jnp.dot(adjacency.astype(jnp.float32), x.astype(jnp.float32))
    return jnp.dot(agg, w.astype(jnp.float32)).astype(x.dtype)


def edge_list_aggregate_ref(x: jax.Array, senders: jax.Array,
                            receivers: jax.Array, weights: jax.Array,
                            n_nodes: int) -> jax.Array:
    """Edge-list semantics the block-dense adjacency must reproduce."""
    msgs = x[senders] * weights[:, None]
    return jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """(B, S, H, D) attention oracle in fp32."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def embedding_bag_ref(table: jax.Array, indices: jax.Array) -> jax.Array:
    """(V, D) table, (B, hot) indices -> (B, D) summed bags."""
    return jnp.take(table, indices, axis=0).sum(axis=1)
