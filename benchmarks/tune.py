"""Auto-tuner benchmark: ``PYTHONPATH=src python -m benchmarks.tune``.

Gates the §15 design-space auto-tuner's two load-bearing claims:

* **Oracle parity** — on three small search spaces (uniform full-graph
  with residency/capacity axes, uniform with n_tiles/halo axes, and a
  trace tune over the molecule batch) the tuner's winner and every
  evaluated point must be bit-identical to an independent brute force
  (per-candidate planner calls + masked ``np.argmin``).
* **Amortized search at scale** — a 16-point power-of-two capacity
  sweep x all registered dataflows over a 10⁶-edge streaming power-law
  trace must finish within the 5 s CPU budget and perform exactly ONE
  sorted-edge factorization and ONE trace build
  (``trace_cache_info()["stats"]``): capacities batch along the
  planner axis and every dataflow shares the per-capacity schedule LRU.

Pareto sanity (strictly shaped, pairwise non-dominated frontier) rides
along on the big tune.  Disk caching is disabled up front so the
counters measure the in-process machinery, not a warm
``~/.cache/repro-trace``.  Outputs one row per tune and with ``--json``
writes ``BENCH_tune.json`` for PR-over-PR diffing; exits non-zero on
any gate failure (the CI ``tune-smoke`` job runs ``--smoke``).
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

os.environ["REPRO_TRACE_CACHE"] = "0"  # before any trace resolution

import numpy as np

TIME_BUDGET_S = 5.0


def _pow2_caps(n_nodes: int, points: int) -> list[int]:
    caps: list[int] = []
    i = 1
    while len(caps) < points:
        cap = max(1, n_nodes >> i)
        if caps and cap == caps[-1]:
            break
        caps.append(cap)
        i += 1
    return caps


def _oracle_gate(scenario) -> list[str]:
    """Brute-force the space independently; return drift messages."""
    from repro.api import Composition, evaluate_scenario
    from repro.core import registry, tile_working_set_bits, tune_scenario

    opt = scenario.optimize
    space = opt["space"]
    comp = scenario.composition
    if scenario.graph_kind == "trace":
        from repro.core import resolve_trace_dataset
        V = float(resolve_trace_dataset(scenario.graph["dataset"],
                                        scenario.graph["params"]).n_nodes)
    else:
        V = float(scenario.graph["V"])
    dataflows = (registry.names() if space.get("dataflow") == "all"
                 else tuple(space.get("dataflow") or (scenario.dataflow,)))
    residencies = tuple(space.get("residency") or (comp.residency,))
    halos = tuple(space.get("halo_dedup") or (comp.halo_dedup,))
    if "tile_vertices" in space:
        caps = tuple(space["tile_vertices"])
    elif "n_tiles" in space:
        caps = tuple(float(math.ceil(V / nt)) for nt in space["n_tiles"])
    else:
        caps = (float(comp.tile_vertices),)

    objs, srams = [], []
    for df in dataflows:
        sigma = float(scenario.hardware.get(
            "sigma", registry.get(df).hw_factory().sigma))
        for res in residencies:
            for hd in halos:
                for cap in caps:
                    r = evaluate_scenario(scenario.replace(
                        dataflow=df, optimize=None, expect=None,
                        composition=Composition(
                            widths=comp.widths, residency=res,
                            tile_vertices=cap, halo_dedup=hd)))
                    objs.append(float(r.total_bits))
                    srams.append(float(tile_working_set_bits(
                        cap, V=V, widths=(comp.widths
                                          or (scenario.graph["N"],
                                              scenario.graph["T"])),
                        sigma=sigma, residency=res, halo_dedup=hd)))
    best = int(np.argmin(objs))

    tr = tune_scenario(scenario)
    drift = []
    if tr.method != "exhaustive":
        drift.append(f"expected exhaustive sweep, got {tr.method}")
    if tr.n_evaluated != len(objs):
        drift.append(f"evaluated {tr.n_evaluated} points, oracle enumerates "
                     f"{len(objs)}")
    for i, p in enumerate(tr.points):
        if p.index != i or p.objective != objs[i] or p.sram_bits != srams[i]:
            drift.append(f"point {i}: tuner ({p.objective}, {p.sram_bits}) "
                         f"!= oracle ({objs[i]}, {srams[i]})")
    if tr.best.index != best or tr.best.objective != objs[best]:
        drift.append(f"winner: tuner #{tr.best.index} ({tr.best.objective}) "
                     f"!= oracle #{best} ({objs[best]})")
    return drift


def _pareto_gate(tr) -> list[str]:
    drift = []
    fr = tr.frontier
    if not fr:
        return ["empty Pareto frontier on an open-budget tune"]
    for a, b in zip(fr, fr[1:]):
        if not (a.sram_bits < b.sram_bits and a.objective > b.objective):
            drift.append(f"frontier not strictly shaped at sram="
                         f"{b.sram_bits:g}")
    feas = [p for p in tr.points if p.feasible]
    for p in fr:
        for q in feas:
            if q.sram_bits <= p.sram_bits and q.objective < p.objective:
                drift.append(f"frontier point #{p.index} dominated by "
                             f"#{q.index}")
    if fr[-1].objective != tr.best.objective:
        drift.append("frontier does not end at the unconstrained winner")
    return drift


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI budget: 10⁴-edge trace, 8 capacities")
    ap.add_argument("--edges", type=float, default=None,
                    help="override trace edge count (default 1e6 / 1e4)")
    ap.add_argument("--points", type=int, default=None,
                    help="capacity sweep points (default 16 / 8)")
    ap.add_argument("--json", nargs="?", const="BENCH_tune.json",
                    default=None, metavar="PATH")
    args = ap.parse_args(argv)

    from repro.api import Scenario
    from repro.core import (clear_trace_cache, registry, reset_trace_stats,
                            trace_cache_info, tune_scenario)

    n_edges = int(args.edges if args.edges is not None
                  else (1e4 if args.smoke else 1e6))
    points = args.points if args.points is not None else (8 if args.smoke
                                                          else 16)
    n_nodes = 1 << max(8, int(math.log2(max(n_edges // 8, 256))))
    caps = _pow2_caps(n_nodes, points)
    failures: list[str] = []
    report: dict = {"smoke": bool(args.smoke), "gates": {}}

    # -- gate 1: oracle parity on three small spaces -----------------------
    oracle_spaces = [
        ("uniform-residency-capacity", Scenario.full_graph(
            registry.names()[0], V=512.0, E=4096.0, N=64.0, T=8.0,
            widths=(64, 16, 8), tile_vertices=128.0,
            label="tune-bench-uniform",
            optimize={"objective": "movement",
                      "space": {"dataflow": "all",
                                "tile_vertices": [64, 128, 256, 512],
                                "residency": ["spill", "resident"]}})),
        ("uniform-ntiles-halo", Scenario.full_graph(
            registry.names()[0], V=2048.0, E=20480.0, N=32.0, T=8.0,
            widths=(32, 16, 8), tile_vertices=256.0,
            label="tune-bench-halo",
            optimize={"objective": "movement",
                      "space": {"n_tiles": [1, 2, 4, 8],
                                "halo_dedup": [1.0, 2.0, 4.0]}})),
        ("trace-molecule", Scenario.trace(
            registry.names()[0], dataset="molecule",
            params={"batch": 8, "n_nodes": 30, "n_edges": 64, "seed": 0,
                    "step": 0},
            N=16.0, T=16.0, widths=(16, 16, 16), tile_vertices=32.0,
            label="tune-bench-trace",
            optimize={"objective": "movement",
                      "space": {"dataflow": "all",
                                "tile_vertices": [16, 32, 64]}})),
    ]
    t0 = time.perf_counter()
    for name, s in oracle_spaces:
        drift = _oracle_gate(s)
        report["gates"][f"oracle:{name}"] = {"ok": not drift, "drift": drift}
        failures += [f"oracle:{name}: {d}" for d in drift]
        print(f"oracle parity [{name}]: {'OK' if not drift else 'DRIFT'}")
    report["oracle_seconds"] = round(time.perf_counter() - t0, 3)

    # -- gate 2: 16-capacity x all-dataflow tune over a big trace ----------
    big = Scenario.trace(
        registry.names()[0], dataset="power_law_stream",
        params={"alpha": 1.6, "n_nodes": float(n_nodes),
                "n_edges": float(n_edges), "seed": 0},
        N=64.0, T=16.0, widths=(64, 32, 16), tile_vertices=float(caps[0]),
        label=f"tune-bench-powerlaw-{n_edges:g}",
        optimize={"objective": "movement",
                  "space": {"dataflow": "all",
                            "tile_vertices": [float(c) for c in caps]}})
    clear_trace_cache()
    reset_trace_stats()
    t0 = time.perf_counter()
    tr = tune_scenario(big)
    tune_s = time.perf_counter() - t0
    stats = trace_cache_info()["stats"]

    n_df = len(registry.names())
    print(f"big tune: {n_edges:g} edges, {len(caps)} capacities x {n_df} "
          f"dataflows = {tr.n_candidates} candidates in {tune_s:.2f}s "
          f"({tr.n_groups} broadcast groups)")
    print(f"  best: {tr.best.dataflow} tv={tr.best.tile_vertices:g} "
          f"obj={tr.best.objective:.6g} bits "
          f"(frontier: {len(tr.frontier)} points)")
    print(f"  trace stats: {stats}")

    gate = {"seconds": round(tune_s, 3), "stats": dict(stats),
            "n_candidates": tr.n_candidates, "n_groups": tr.n_groups}
    if stats["factorizations"] != 1:
        failures.append(f"big tune ran {stats['factorizations']} "
                        "factorizations; the whole sweep must share ONE")
    if stats["trace_builds"] != 1:
        failures.append(f"big tune ran {stats['trace_builds']} trace builds")
    if tr.n_candidates != len(caps) * n_df:
        failures.append(f"expected {len(caps) * n_df} candidates, "
                        f"evaluated {tr.n_candidates}")
    if not args.smoke and tune_s > TIME_BUDGET_S:
        failures.append(f"big tune took {tune_s:.2f}s "
                        f"(budget {TIME_BUDGET_S:g}s)")
    gate["ok"] = not any(f.startswith("big tune") or "candidates" in f
                         for f in failures)
    report["gates"]["big-tune"] = gate
    report["big_tune"] = tr.to_dict()
    report["big_tune"].pop("points", None)  # keep the JSON diffable

    # -- gate 3: Pareto sanity on the big tune -----------------------------
    drift = _pareto_gate(tr)
    report["gates"]["pareto"] = {"ok": not drift, "drift": drift}
    failures += [f"pareto: {d}" for d in drift]
    print(f"pareto frontier: {'OK' if not drift else 'DRIFT'}")

    report["status"] = "ok" if not failures else "failed"
    for f in failures:
        print(f"# GATE FAILURE: {f}", file=sys.stderr)
    if args.json is not None:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
