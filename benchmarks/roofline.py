"""§Roofline: three-term roofline per (arch x shape) from the dry-run
artifacts (single-pod mesh per the brief; the multi-pod pass proves the pod
axis shards).

Reads results/dryrun/single/*.json, emits one row per cell with:
  compute_s / memory_s / collective_s, the dominant term, MODEL_FLOPS,
  the useful-FLOP ratio, and the achieved roofline fraction.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.tpu_model import RooflineReport, roofline

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_reports(mesh: str = "single") -> list[RooflineReport]:
    """XLA's cost analysis counts a while/scan body ONCE (verified by a
    layer-count probe, EXPERIMENTS.md §Roofline); every record carries the
    layer-loop trip count as ``loop_scale`` and all three terms scale by it.
    Residual undercount from inner chunk loops (q-chunks, CE chunks) is
    documented per cell."""
    reports = []
    for p in sorted((RESULTS / mesh).glob("*.json")):
        rec = json.loads(p.read_text())
        if not rec.get("ok"):
            continue
        scale = float(rec.get("meta", {}).get("loop_scale", 1) or 1)
        reports.append(roofline(
            cell=f"{rec['arch']}::{rec['shape']}",
            chips=rec["chips"],
            flops_per_chip=rec["cost"]["flops"] * scale,
            hbm_bytes_per_chip=rec["cost"]["bytes_accessed"] * scale,
            collective_bytes_per_chip=(
                rec["collectives"]["wire_bytes_per_chip"] * scale),
            model_flops=rec["model_flops"],
            meta={"kind": rec.get("kind"), "mesh": mesh, "loop_scale": scale},
        ))
    return reports


def rows(mesh: str = "single") -> list[dict]:
    out = []
    for rep in load_reports(mesh):
        r = rep.row()
        r["mesh"] = mesh
        out.append(r)
    return out


def render_table(mesh: str = "single") -> str:
    lines = [f"{'cell':<42}{'compute_s':>11}{'memory_s':>11}{'coll_s':>11}"
             f"{'dominant':>11}{'useful':>8}{'roofl%':>8}"]
    for r in rows(mesh):
        lines.append(
            f"{r['cell']:<42}{r['compute_s']:>11.3e}{r['memory_s']:>11.3e}"
            f"{r['collective_s']:>11.3e}{r['dominant']:>11}"
            f"{r['useful_flop_ratio']:>8.2f}"
            f"{100 * r['roofline_fraction']:>7.1f}%")
    return "\n".join(lines)


if __name__ == "__main__":
    print(render_table())
