"""Throughput-under-load benchmark for the §18 scenario-serving engine.

The first benchmark in the repo that measures *service* behaviour rather
than single-shot wall time: a synthetic many-client load of mixed
tile / full-graph / trace / hetero / minibatch / tune requests, sampled
with heavy duplication from a small scenario pool, is driven through

* the **naive per-request loop** — one ``evaluate_scenarios`` call per
  request, exactly what N independent CLI invocations would cost with
  warm in-process caches; and
* the **serve engine** — every request submitted concurrently from
  client threads into :class:`repro.api.serve.ServeEngine`, which
  coalesces identical scenarios across requests inside micro-batching
  windows and shares one broadcast evaluation per plan group.

Both paths run against warm caches, so the measured gap is pure
cross-request coalescing + planner amortization, not cold-start noise.

Gates (exit 1 on failure, ``# GATE FAILURE`` lines on stderr):

* **drift** — every served result must be bit-identical to the serial
  oracle (total/offchip/cache/onchip bits, iterations, every breakdown
  term).  The serve engine evaluates through the same planner, so any
  drift is a scatter bug.
* **coalesce** — a duplicate-heavy load must show a coalesce rate > 0
  (N duplicate requests -> fewer evaluations than scenarios).
* **speedup** (full mode only) — served scenarios/sec must be >= 10x
  the naive loop's.

``--smoke`` keeps the request count CI-sized; the committed
``BENCH_serve.json`` comes from a full run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# Hermetic by default: the disk cache participates through a throwaway
# root (shared-warm-store counters show up in the report) unless the
# caller pinned one.  Must happen before repro imports read the env.
_TMP_CACHE = None
if "REPRO_TRACE_CACHE" not in os.environ:
    _TMP_CACHE = tempfile.TemporaryDirectory(prefix="repro-serve-bench-")
    os.environ["REPRO_TRACE_CACHE"] = _TMP_CACHE.name
    os.environ.setdefault("REPRO_TRACE_CACHE_MIN_EDGES", "0")

import numpy as np

from repro.api import Scenario, ServeEngine, evaluate_scenarios
from repro.core import registry, schedule_cache
from repro.core.trace import reset_trace_stats, trace_cache_info

TRACE_PARAMS = {"n_nodes": 4000.0, "n_edges": 16000.0, "seed": 1.0}
TYPED_PARAMS = {"n_nodes": 2000.0, "n_edges": 12000.0, "seed": 0.0}


def build_pool() -> list[Scenario]:
    """~24 distinct scenarios across every kind the front door serves."""
    dataflows = list(registry.names())
    pool: list[Scenario] = []
    for df in dataflows:
        for K in (256.0, 1024.0, 4096.0):
            pool.append(Scenario.tile(
                df, K=K, label=f"tile-{df}-{int(K)}", workload="serve-load"))
    for df in dataflows[:2]:
        pool.append(Scenario.full_graph(
            df, V=2708.0, E=10556.0, N=1433.0, T=7.0,
            widths=(1433.0, 16.0, 7.0), tile_vertices=512.0,
            label=f"full-{df}", workload="serve-load"))
    for df in dataflows[:2]:
        for cap in (256.0, 1024.0):
            pool.append(Scenario.trace(
                df, dataset="power_law", params=TRACE_PARAMS,
                N=64.0, T=16.0, tile_vertices=cap,
                widths=(64.0, 32.0, 16.0),
                label=f"trace-{df}-{int(cap)}", workload="serve-load"))
    pool.append(Scenario.hetero(
        dataflows[0], dataset="typed_power_law", n_relations=3,
        params=TYPED_PARAMS, N=[30.0, 20.0, 10.0], T=5.0,
        tile_vertices=512.0, label="hetero-serve", workload="serve-load"))
    pool.append(Scenario.minibatch(
        dataflows[1], dataset="power_law", params=TRACE_PARAMS,
        batch_nodes=64, fanout=(4, 4), n_batches=4, N=64.0, T=16.0,
        label="minibatch-serve", workload="serve-load"))
    pool.append(Scenario.trace(
        dataflows[0], dataset="power_law", params=TRACE_PARAMS,
        N=32.0, T=8.0, tile_vertices=512.0,
        optimize={"objective": "movement",
                  "space": {"tile_vertices": [256.0, 512.0, 1024.0]}},
        label="tune-serve", workload="serve-load"))
    return pool


def build_requests(pool, n_requests: int, seed: int) -> list[list[Scenario]]:
    """Duplicate-heavy load: each request samples 1-3 pool scenarios."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 4, size=n_requests)
    return [[pool[i] for i in rng.integers(0, len(pool), size=int(k))]
            for k in sizes]


def _result_record(r) -> dict:
    return {
        "total_bits": r.total_bits,
        "total_iterations": r.total_iterations,
        "offchip_bits": r.offchip_bits,
        "cache_bits": r.cache_bits,
        "onchip_bits": r.onchip_bits,
        "breakdown": dict(r.breakdown),
        "iteration_breakdown": dict(r.iteration_breakdown),
        "n_tiles": r.n_tiles,
    }


def drift_gate(serial, served) -> list[str]:
    """Bit-exact comparison of every per-request result pair."""
    drift = []
    for i, (a_req, b_req) in enumerate(zip(serial, served)):
        if len(a_req) != len(b_req):
            drift.append(f"request {i}: {len(a_req)} serial results vs "
                         f"{len(b_req)} served")
            continue
        for j, (a, b) in enumerate(zip(a_req, b_req)):
            ra, rb = _result_record(a), _result_record(b)
            if ra != rb:
                keys = [k for k in ra if ra[k] != rb[k]]
                drift.append(f"request {i} scenario {j} "
                             f"({a.scenario.label}): fields {keys} differ "
                             f"(e.g. {keys[0]}: {ra[keys[0]]!r} vs "
                             f"{rb[keys[0]]!r})")
            if len(drift) > 20:
                return drift
    return drift


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.serve",
        description="Serve-engine throughput benchmark: coalesced "
                    "concurrent requests vs the naive per-request loop.")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized load (fewer requests, no speedup gate)")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default 1500; smoke 300)")
    ap.add_argument("--clients", type=int, default=16,
                    help="concurrent submitter threads (default 16)")
    ap.add_argument("--window", type=float, default=0.002,
                    help="serve micro-batching window seconds "
                         "(default 0.002)")
    ap.add_argument("--pool-size", type=int, default=None,
                    help="truncate the scenario pool (smaller pool -> "
                         "higher duplicate ratio)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark report JSON")
    args = ap.parse_args(argv)

    n_requests = args.requests or (300 if args.smoke else 1500)
    pool = build_pool()
    if args.pool_size is not None:
        pool = pool[:max(1, args.pool_size)]
    requests = build_requests(pool, n_requests, args.seed)
    n_scen = sum(len(r) for r in requests)
    distinct_used = len({s for req in requests for s in req})
    dup_ratio = 1.0 - distinct_used / n_scen

    print(f"# load: {n_requests} requests / {n_scen} scenarios, "
          f"{distinct_used} distinct (duplicate ratio {dup_ratio:.3f}), "
          f"pool {len(pool)}")

    # Warm both paths identically: resolve every trace, compute every
    # schedule, run the tuner once.  From here on the gap is coalescing.
    evaluate_scenarios(pool)
    reset_trace_stats()
    schedule_cache.reset_cache_stats()

    # -- naive per-request loop -------------------------------------------
    t0 = time.perf_counter()
    serial = [evaluate_scenarios(req).results for req in requests]
    naive_s = time.perf_counter() - t0
    naive_rate = n_scen / naive_s
    print(f"# naive loop: {naive_s:.3f}s ({naive_rate:,.0f} scenarios/sec)")

    # -- served, coalesced ------------------------------------------------
    from concurrent.futures import ThreadPoolExecutor

    reset_trace_stats()
    stats0 = trace_cache_info()["stats"]
    engine = ServeEngine(window_s=args.window)
    n_clients = max(1, args.clients)
    # Each client owns an interleaved slice of the request stream and
    # fires it as fast as the engine accepts — the closed-loop burst a
    # fleet of independent callers produces.
    chunks = [requests[c::n_clients] for c in range(n_clients)]
    t0 = time.perf_counter()
    with engine:
        with ThreadPoolExecutor(max_workers=n_clients) as pool_ex:
            chunk_handles = list(pool_ex.map(
                lambda reqs: [engine.submit_future(r) for r in reqs],
                chunks))
        handles = [None] * len(requests)
        for c, hs in enumerate(chunk_handles):
            for k, h in enumerate(hs):
                handles[c + k * n_clients] = h
        served_results = [h.result() for h in handles]
    served_s = time.perf_counter() - t0
    stats1 = trace_cache_info()["stats"]
    served = [sr.results for sr in served_results]
    served_rate = n_scen / served_s
    latencies_ms = np.array([sr.serve["latency_s"] * 1e3
                             for sr in served_results])
    metrics = engine.metrics()
    speedup = served_rate / naive_rate
    print(f"# served: {served_s:.3f}s ({served_rate:,.0f} scenarios/sec), "
          f"{metrics['windows']} windows, "
          f"{metrics['evaluations']} evaluations, "
          f"coalesce rate {metrics['coalesce_rate']:.3f}")
    print(f"# latency p50 {np.percentile(latencies_ms, 50):.1f}ms "
          f"p99 {np.percentile(latencies_ms, 99):.1f}ms; "
          f"speedup {speedup:.1f}x")

    # -- gates ------------------------------------------------------------
    drift = drift_gate(serial, served)
    gates = {
        "drift_ok": not drift,
        "coalesce_ok": metrics["coalesce_rate"] > 0.0,
        "speedup_ok": bool(args.smoke or speedup >= 10.0),
    }
    for line in drift:
        print(f"# GATE FAILURE drift: {line}", file=sys.stderr)
    if not gates["coalesce_ok"]:
        print(f"# GATE FAILURE coalesce: rate "
              f"{metrics['coalesce_rate']} under duplicate ratio "
              f"{dup_ratio:.3f}", file=sys.stderr)
    if not gates["speedup_ok"]:
        print(f"# GATE FAILURE speedup: {speedup:.2f}x < 10x",
              file=sys.stderr)

    report = {
        "config": {
            "smoke": args.smoke, "requests": n_requests,
            "clients": args.clients, "window_s": args.window,
            "pool": len(pool), "seed": args.seed,
        },
        "load": {
            "scenarios": n_scen,
            "distinct_scenarios": distinct_used,
            "duplicate_ratio": dup_ratio,
            "kinds": sorted({("tune" if s.optimize is not None
                              else s.graph_kind) for s in pool}),
        },
        "naive": {"seconds": naive_s, "scenarios_per_sec": naive_rate},
        "served": {
            "seconds": served_s,
            "scenarios_per_sec": served_rate,
            "latency_ms_p50": float(np.percentile(latencies_ms, 50)),
            "latency_ms_p99": float(np.percentile(latencies_ms, 99)),
            "windows": metrics["windows"],
            "evaluations": metrics["evaluations"],
            "coalesce_rate": metrics["coalesce_rate"],
            "fallback_windows": metrics["fallback_windows"],
            "trace_stats": {k: stats1[k] - stats0[k] for k in stats1},
        },
        "speedup": speedup,
        "disk_cache": schedule_cache.cache_stats(),
        "gates": gates,
        "drift": drift,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
