"""Conformance runner: ``PYTHONPATH=src python -m benchmarks.conformance``.

Compiles the runnable kernel analogues of every registered dataflow across
the operating-point sweep, prints one CSV row per
:class:`~repro.core.conformance.ConformanceRecord` (analytical vs measured
bytes, ratio, declared tolerance), and exits non-zero if any record fails —
the command-line form of the guarantee in DESIGN.md §10.

``--json [PATH]`` additionally writes a machine-readable summary (default
``BENCH_conformance.json``, same top-level shape as ``BENCH_sweep.json``:
a ``benchmarks`` timing block, plus the per-record rows) so future PRs can
diff the measured trajectory.  ``--execute`` also runs the kernels in
interpret mode against the jnp oracle (slower; compile-only by default).
``--points M`` truncates the sweep for smoke runs.
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import sys
import time

#: ``--execute`` fails the run when the kernels' max relative error vs the
#: jnp oracle reaches this (same bar as tests/test_conformance.py).
NUMERICS_REL_TOL = 1e-5


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_conformance.json",
                    default=None, metavar="PATH",
                    help="also write a summary JSON (default "
                         "BENCH_conformance.json)")
    ap.add_argument("--points", type=int, default=None, metavar="M",
                    help="truncate the operating-point sweep to M points")
    ap.add_argument("--execute", action="store_true",
                    help="also execute the kernels (interpret mode) against "
                         "the jnp oracle at each point")
    args = ap.parse_args(argv)

    from repro.core.conformance import (default_operating_points,
                                        run_conformance, summarize_records,
                                        verify_numerics)

    points = default_operating_points()
    if args.points is not None:
        points = points[:args.points]

    t0 = time.perf_counter()
    records = run_conformance(points=points)
    elapsed = time.perf_counter() - t0

    rows = [r.as_row() for r in records]
    cols = sorted({k for r in rows for k in r})
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(f"# ==== conformance ({len(rows)} records, "
          f"{len(points)} operating points) ====")
    print(buf.getvalue())

    numerics = None
    numerics_ok = True
    if args.execute:
        numerics = max(verify_numerics(pt) for pt in points)
        numerics_ok = numerics < NUMERICS_REL_TOL
        print(f"# numerics max relative error vs jnp oracle: {numerics:.3e} "
              f"(tolerance {NUMERICS_REL_TOL:.0e})")

    summary = summarize_records(records)
    summary["elapsed_s"] = elapsed
    if numerics is not None:
        summary["numerics_max_rel_err"] = numerics
    print(f"# summary: {json.dumps(summary['by_dataflow'], sort_keys=True)}")

    if args.json is not None:
        payload = {
            "benchmarks": {
                "conformance": {
                    "us_per_call": 1e6 * elapsed / max(len(records), 1),
                    "n_rows": len(records),
                },
            },
            "conformance": summary,
            "records": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json} ({len(rows)} records)")

    if not summary["all_ok"]:
        failing = [str(r) for r in records if not r.ok]
        print("# CONFORMANCE FAILURES:", *failing, sep="\n# ", file=sys.stderr)
        return 1
    if not numerics_ok:
        print(f"# NUMERICS FAILURE: max relative error {numerics:.3e} "
              f">= {NUMERICS_REL_TOL:.0e}", file=sys.stderr)
        return 1
    print("# all conformance records within declared tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
