import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> measure.

Three cells (per the brief's selection rule):
  * gcn-cora x ogb_products        — most representative of the paper
                                     (GCN, collective-bound, worst useful ratio)
  * equiformer-v2 x ogb_products   — most collective-bound cell of the grid
  * qwen3-moe-30b-a3b x train_4k   — the MoE-a2a cell (paper's methodology
                                     generalized), memory-bound

Each experiment compiles a VARIANT of the baseline plan and records the
roofline terms to results/hillclimb/<name>.json.  The narrative lives in
EXPERIMENTS.md §Perf.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--only NAME]
"""

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.hlo_analysis import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

RESULTS = Path(__file__).resolve().parents[1] / "results" / "hillclimb"


def measure(plan, mesh, scale: float) -> dict:
    lowered = plan.lower(mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    stats = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops_per_chip": float(cost.get("flops", 0.0)) * scale,
        "hbm_bytes_per_chip": float(cost.get("bytes accessed", 0.0)) * scale,
        "collective_bytes_per_chip": stats.total_wire_bytes_per_chip * scale,
        "by_kind": {k: v * scale for k, v in stats.by_kind().items()},
        "temp_bytes": mem.temp_size_in_bytes,
        "loop_scale": scale,
    }


def record(name: str, baseline: dict, variants: dict[str, dict],
           hypothesis: str) -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    rec = {"name": name, "hypothesis": hypothesis, "baseline": baseline,
           "variants": variants}
    (RESULTS / f"{name}.json").write_text(json.dumps(rec, indent=2))
    return rec


# ---------------------------------------------------------------------------
# HC-1: gcn-cora x ogb_products
# ---------------------------------------------------------------------------

def hc_gcn() -> dict:
    mesh = make_production_mesh()
    base_plan = build_cell("gcn-cora", "ogb_products", mesh)
    baseline = measure(base_plan, mesh, 1.0)

    # Variant A — bf16 feature pipeline: the wire traffic is raw node
    # features/activations; casting the aggregation path to bf16 should
    # halve both the all-gather and the scatter all-reduce bytes.
    # Variant A — aggregate in bf16: the transformed features crossing the
    # wire (gather of h, scatter all-reduce) halve in width; the dense
    # transforms stay f32.
    from repro.models.gnn import gcn as gcn_mod
    plan_a = build_cell("gcn-cora", "ogb_products", mesh)
    orig_loss = gcn_mod.loss_fn
    orig_fwd = gcn_mod.forward

    def fwd_bf16(cfg, params, g, **kw):
        kw["agg_dtype"] = jnp.bfloat16
        return orig_fwd(cfg, params, g, **kw)

    gcn_mod.forward = fwd_bf16
    try:
        plan_a = build_cell("gcn-cora", "ogb_products", mesh)
        var_a = measure(plan_a, mesh, 1.0)
    finally:
        gcn_mod.forward = orig_fwd

    # Variant B — nodes/edges sharded over dp only (16-way) instead of all
    # 256: the scatter-add's partial-sum all-reduce spans 16 ranks instead
    # of 256, trading parallel width for collective span.
    from repro.launch import steps as steps_mod
    orig_specs = steps_mod._gnn_graph_specs

    def dp_only_specs(arch, g, policy, shape):
        if arch.name == "gcn-cora":
            arch = __import__("dataclasses").replace(arch, name="meshgraphnet")
            out = orig_specs(arch, g, policy, shape)
            return out
        return orig_specs(arch, g, policy, shape)

    steps_mod._gnn_graph_specs = dp_only_specs
    try:
        plan_b = build_cell("gcn-cora", "ogb_products", mesh)
        var_b = measure(plan_b, mesh, 1.0)
    finally:
        steps_mod._gnn_graph_specs = orig_specs

    return record(
        "gcn_ogb_products", baseline,
        {"bf16_aggregation": var_a, "dp_only_sharding": var_b},
        hypothesis="collective term is feature bytes on the wire "
                   "(all-gather of transformed features + all-reduce of the "
                   "scatter); bf16 aggregation halves it / narrowing the "
                   "scatter's collective span shrinks the all-reduce")


# ---------------------------------------------------------------------------
# HC-2: equiformer-v2 x ogb_products
# ---------------------------------------------------------------------------

def hc_eqv2(gather_once: bool) -> dict:
    """Variant is toggled through the module flag GATHER_ONCE (see
    equiformer_v2._GATHER_ONCE) — gather/replicate node features once per
    layer instead of per edge chunk."""
    from repro.models.gnn import equiformer_v2 as eqv2
    mesh = make_production_mesh()
    eqv2._GATHER_ONCE = False
    base_plan = build_cell("equiformer-v2", "ogb_products", mesh)
    baseline = measure(base_plan, mesh, 12.0)
    eqv2._GATHER_ONCE = gather_once
    var_plan = build_cell("equiformer-v2", "ogb_products", mesh)
    variant = measure(var_plan, mesh, 12.0)
    eqv2._GATHER_ONCE = False
    return record(
        "eqv2_ogb_products", baseline, {"gather_once_per_layer": variant},
        hypothesis="the 64-chunk conv loop re-all-gathers the (N, L2, C/tp) "
                   "feature tensor every chunk (64x3.84 GB/layer on the "
                   "wire); hoisting one gather per layer cuts the all-gather "
                   "term ~64x at a +3.84 GB/device working-set cost")


# ---------------------------------------------------------------------------
# HC-3: qwen3-moe x train_4k
# ---------------------------------------------------------------------------

def _patched_arch(name: str, cfg_transform):
    """Temporarily swap REGISTRY[name] for a variant whose make_config is
    post-processed by ``cfg_transform`` (build_cell reads the registry)."""
    import contextlib
    import dataclasses
    from repro import configs as cfg_mod

    @contextlib.contextmanager
    def ctx():
        orig = cfg_mod.REGISTRY[name]
        patched = dataclasses.replace(
            orig, make_config=lambda **kw: cfg_transform(orig.make_config(**kw)))
        cfg_mod.REGISTRY[name] = patched
        try:
            yield
        finally:
            cfg_mod.REGISTRY[name] = orig

    return ctx()


def hc_qwen3() -> dict:
    import dataclasses
    mesh = make_production_mesh()

    base_plan = build_cell("qwen3-moe-30b-a3b", "train_4k", mesh)
    baseline = measure(base_plan, mesh, 48.0)

    # Variant A — remat "dots": save matmul outputs instead of full remat;
    # memory term should drop (no FFN recompute reads) at temp-bytes cost.
    with _patched_arch("qwen3-moe-30b-a3b",
                       lambda c: dataclasses.replace(c, remat="dots")):
        var_a = measure(build_cell("qwen3-moe-30b-a3b", "train_4k", mesh),
                        mesh, 48.0)

    # Variant B — tighter MoE capacity (1.25 -> 1.0): a2a payload and expert
    # GEMM bytes scale with capacity; 20% less dispatch traffic for a known,
    # bounded drop rate.
    with _patched_arch("qwen3-moe-30b-a3b",
                       lambda c: dataclasses.replace(
                           c, moe=dataclasses.replace(c.moe, capacity_factor=1.0))):
        var_b = measure(build_cell("qwen3-moe-30b-a3b", "train_4k", mesh),
                        mesh, 48.0)

    return record(
        "qwen3_train_4k", baseline,
        {"remat_dots": var_a, "capacity_1.0": var_b},
        hypothesis="memory term dominates: full remat re-reads every weight "
                   "in the backward recompute, and the MoE dispatch buffers "
                   "scale with the capacity factor")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    runs = {
        "gcn": hc_gcn,
        "eqv2": lambda: hc_eqv2(True),
        "qwen3": hc_qwen3,
    }
    for name, fn in runs.items():
        if args.only and args.only != name:
            continue
        rec = fn()
        b = rec["baseline"]
        print(f"== {rec['name']} ==")
        print(f"   baseline: flops={b['flops_per_chip']:.3e} "
              f"hbm={b['hbm_bytes_per_chip']:.3e} "
              f"coll={b['collective_bytes_per_chip']:.3e}")
        for vn, v in rec["variants"].items():
            print(f"   {vn:>22}: flops={v['flops_per_chip']:.3e} "
                  f"hbm={v['hbm_bytes_per_chip']:.3e} "
                  f"coll={v['collective_bytes_per_chip']:.3e} "
                  f"(x{v['collective_bytes_per_chip']/max(b['collective_bytes_per_chip'],1):.2f} coll, "
                  f"x{v['hbm_bytes_per_chip']/max(b['hbm_bytes_per_chip'],1):.2f} hbm)")


if __name__ == "__main__":
    main()
