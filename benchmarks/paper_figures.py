"""One benchmark per paper artifact (Figs. 3-7) — each returns CSV rows and
a wall-time per evaluation (the analytical models are vectorized closed
forms, so the timing quantifies the sweep engine itself).

Every benchmark here routes through the scenario front door
(:mod:`repro.api`, DESIGN.md §11): the figures via the named templates
behind the ``figN_*`` sweep functions, the composition and workload
studies as explicit scenario batches handed to the batch planner."""

from __future__ import annotations

import time

import numpy as np

from repro.api import evaluate_scenarios, template
from repro.core import registry
from repro.core.sweep import (fig3_engn_movement, fig4_hygcn_movement,
                              fig5_iterations_vs_bandwidth,
                              fig6_fitting_factor, fig7_systolic_reuse,
                              sweep_accelerators)


def _timed(fn, *args, repeats: int = 20, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        res = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return res, dt * 1e6


def fig3() -> list[dict]:
    res, us = _timed(fig3_engn_movement)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig3_engn_movement", us_per_call=us)
    return rows


def fig4() -> list[dict]:
    res, us = _timed(fig4_hygcn_movement)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig4_hygcn_movement", us_per_call=us)
    return rows


def fig5() -> list[dict]:
    out = []
    for accel in ("engn", "hygcn"):
        res, us = _timed(fig5_iterations_vs_bandwidth, accel)
        for r in res.rows():
            r.update(figure=f"fig5_{accel}", us_per_call=us)
            out.append(r)
    return out


def fig6() -> list[dict]:
    res, us = _timed(fig6_fitting_factor)
    ff = np.asarray(res.meta["fitting_factor"])
    rows = res.rows()
    for r, f in zip(rows, ff):
        r.update(figure="fig6_fitting_factor", fitting_factor=float(f),
                 us_per_call=us)
    return rows


def fig7() -> list[dict]:
    res, us = _timed(fig7_systolic_reuse)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig7_systolic_reuse", us_per_call=us)
    return rows


def sweep_all() -> list[dict]:
    """Every registered accelerator over the default K grid, one stacked call."""
    res, us = _timed(sweep_accelerators)
    rows = res.rows()
    for r in rows:
        r.update(figure="sweep_all_accelerators", us_per_call=us)
    return rows


def cora_end_to_end() -> list[dict]:
    """Full-graph composition: 2-layer GCN on Cora for every accelerator,
    one scenario batch — the planner stacks the tile-capacity grid and
    evaluates each dataflow in a single broadcast call."""
    tb = template("cora_end_to_end")
    res, us = _timed(evaluate_scenarios, tb.scenarios)
    assert res.n_evaluations == len(registry.names())
    rows = []
    for r in res.results:
        s = r.scenario
        rows.append({
            "figure": "cora_end_to_end", "accelerator": s.dataflow,
            "tile_vertices": s.composition.tile_vertices,
            "n_tiles": r.n_tiles,
            "total_bits": r.total_bits, "offchip_bits": r.offchip_bits,
            "halo_bits": r.breakdown["haloreload"], "us_per_call": us,
        })
    return rows


def workloads() -> list[dict]:
    """The configs' §5 tile-language bridges: every (workload shape x
    dataflow) movement total as one declarative scenario batch."""
    from repro.configs import workload_scenarios

    archs = ("smollm-135m", "gemma2-2b", "equiformer-v2", "dlrm-mlperf")
    scenarios = workload_scenarios(archs)
    res, us = _timed(evaluate_scenarios, scenarios)
    rows = []
    for r in res.results:
        rows.append({
            "figure": "workload_scenarios",
            "workload": r.scenario.workload,
            "accelerator": r.scenario.dataflow,
            "total_bits": r.total_bits,
            "total_iterations": r.total_iterations,
            "offchip_bits": r.offchip_bits,
            "n_evaluations": res.n_evaluations,
            "us_per_call": us,
        })
    return rows


ALL = (fig3, fig4, fig5, fig6, fig7, sweep_all, cora_end_to_end, workloads)
