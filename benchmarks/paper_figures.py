"""One benchmark per paper artifact (Figs. 3-7) — each returns CSV rows and
a wall-time per evaluation (the analytical models are vectorized closed
forms, so the timing quantifies the sweep engine itself)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.sweep import (fig3_engn_movement, fig4_hygcn_movement,
                              fig5_iterations_vs_bandwidth,
                              fig6_fitting_factor, fig7_systolic_reuse)


def _timed(fn, *args, repeats: int = 20, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        res = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return res, dt * 1e6


def fig3() -> list[dict]:
    res, us = _timed(fig3_engn_movement)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig3_engn_movement", us_per_call=us)
    return rows


def fig4() -> list[dict]:
    res, us = _timed(fig4_hygcn_movement)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig4_hygcn_movement", us_per_call=us)
    return rows


def fig5() -> list[dict]:
    out = []
    for accel in ("engn", "hygcn"):
        res, us = _timed(fig5_iterations_vs_bandwidth, accel)
        for r in res.rows():
            r.update(figure=f"fig5_{accel}", us_per_call=us)
            out.append(r)
    return out


def fig6() -> list[dict]:
    res, us = _timed(fig6_fitting_factor)
    ff = np.asarray(res.meta["fitting_factor"])
    rows = res.rows()
    for r, f in zip(rows, ff):
        r.update(figure="fig6_fitting_factor", fitting_factor=float(f),
                 us_per_call=us)
    return rows


def fig7() -> list[dict]:
    res, us = _timed(fig7_systolic_reuse)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig7_systolic_reuse", us_per_call=us)
    return rows


ALL = (fig3, fig4, fig5, fig6, fig7)
