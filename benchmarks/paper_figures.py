"""One benchmark per paper artifact (Figs. 3-7) — each returns CSV rows and
a wall-time per evaluation (the analytical models are vectorized closed
forms, so the timing quantifies the sweep engine itself)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (FullGraphParams, MultiLayerModel, TiledGraphModel,
                        registry)
from repro.core.sweep import (fig3_engn_movement, fig4_hygcn_movement,
                              fig5_iterations_vs_bandwidth,
                              fig6_fitting_factor, fig7_systolic_reuse,
                              sweep_accelerators)


def _timed(fn, *args, repeats: int = 20, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        res = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return res, dt * 1e6


def fig3() -> list[dict]:
    res, us = _timed(fig3_engn_movement)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig3_engn_movement", us_per_call=us)
    return rows


def fig4() -> list[dict]:
    res, us = _timed(fig4_hygcn_movement)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig4_hygcn_movement", us_per_call=us)
    return rows


def fig5() -> list[dict]:
    out = []
    for accel in ("engn", "hygcn"):
        res, us = _timed(fig5_iterations_vs_bandwidth, accel)
        for r in res.rows():
            r.update(figure=f"fig5_{accel}", us_per_call=us)
            out.append(r)
    return out


def fig6() -> list[dict]:
    res, us = _timed(fig6_fitting_factor)
    ff = np.asarray(res.meta["fitting_factor"])
    rows = res.rows()
    for r, f in zip(rows, ff):
        r.update(figure="fig6_fitting_factor", fitting_factor=float(f),
                 us_per_call=us)
    return rows


def fig7() -> list[dict]:
    res, us = _timed(fig7_systolic_reuse)
    rows = res.rows()
    for r in rows:
        r.update(figure="fig7_systolic_reuse", us_per_call=us)
    return rows


def sweep_all() -> list[dict]:
    """Every registered accelerator over the default K grid, one stacked call."""
    res, us = _timed(sweep_accelerators)
    rows = res.rows()
    for r in rows:
        r.update(figure="sweep_all_accelerators", us_per_call=us)
    return rows


def cora_end_to_end() -> list[dict]:
    """Full-graph composition: 2-layer GCN on Cora for every accelerator,
    vectorized across a tile-capacity grid in a single call per dataflow."""
    tile_caps = np.array([256, 512, 1024, 2048], dtype=np.float64)
    cora = FullGraphParams(V=2708, E=10556, N=1433, T=7)

    def run():
        outs = {}
        for name in registry.names():
            model = TiledGraphModel(MultiLayerModel(name, [1433, 16, 7]),
                                    tile_vertices=tile_caps)
            outs[name] = model.evaluate(cora)
        return outs

    outs, us = _timed(run)
    rows = []
    for name, out in outs.items():
        n_tiles = np.broadcast_to(out.meta["n_tiles"], tile_caps.shape)
        total = np.broadcast_to(out.total_bits(), tile_caps.shape)
        offchip = np.broadcast_to(out.offchip_bits(), tile_caps.shape)
        halo = np.broadcast_to(out["haloreload"].data_bits, tile_caps.shape)
        for i, cap in enumerate(tile_caps):
            rows.append({
                "figure": "cora_end_to_end", "accelerator": name,
                "tile_vertices": float(cap), "n_tiles": float(n_tiles[i]),
                "total_bits": float(total[i]), "offchip_bits": float(offchip[i]),
                "halo_bits": float(halo[i]), "us_per_call": us,
            })
    return rows


ALL = (fig3, fig4, fig5, fig6, fig7, sweep_all, cora_end_to_end)
