"""Analytical-vs-trace halo gap: ``PYTHONPATH=src python -m benchmarks.trace_gap``.

The first result in this repo the paper could not produce: the paper's
composition layer estimates inter-tile halo traffic with the
random-partition expected cut ``E * (1 - 1/n_tiles)`` over uniform tiles,
while the §12 trace backend counts the exact per-tile unique remote
sources of a *real* edge list.  This benchmark sweeps the power-law
exponent of the synthetic preferential-attachment graph (the workload
imbalance the paper highlights) and quantifies, per (alpha, tile
capacity):

* the exact unique-remote-source halo vs the closed-form estimate (the
  estimate ignores both clustering and within-tile source dedup, so it
  overshoots more as hubs concentrate traffic);
* per-tile edge imbalance (max/mean destination edges — uniform tiles
  assume 1.0);
* the degree-aware cache hit fraction at the default L = K/10 split;
* end-to-end scenario totals for a reference dataflow both ways
  (uniform ``full`` scenario vs exact ``trace`` scenario through
  ``repro.api.evaluate_scenarios``).

Prints one CSV row per (alpha, capacity) and with ``--json`` writes
``BENCH_trace.json`` for PR-over-PR diffing.
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_trace.json",
                    default=None, metavar="PATH",
                    help="also write a summary JSON (default BENCH_trace.json)")
    ap.add_argument("--n-nodes", type=int, default=20000)
    ap.add_argument("--n-edges", type=int, default=120000)
    ap.add_argument("--alphas", default="0.5,1.0,1.5,2.0,2.5",
                    help="comma-separated power-law exponents to sweep")
    ap.add_argument("--tile-vertices", default="512,1024,2048",
                    help="comma-separated tile capacities")
    ap.add_argument("--dataflow", default="engn",
                    help="reference dataflow for the end-to-end totals")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.api import Scenario, evaluate_scenarios
    from repro.core.trace import resolve_trace_dataset

    alphas = [float(a) for a in args.alphas.split(",")]
    caps = [int(c) for c in args.tile_vertices.split(",")]

    t0 = time.perf_counter()
    rows = []
    scenarios = []
    for alpha in alphas:
        params = {"n_nodes": args.n_nodes, "n_edges": args.n_edges,
                  "seed": args.seed, "alpha": alpha}
        trace = resolve_trace_dataset("power_law", params)
        for cap in caps:
            sched = trace.schedule(cap)
            stats = sched.stats()
            rows.append({"alpha": alpha, "tile_vertices": cap, **stats})
            scenarios.append(Scenario.trace(
                args.dataflow, dataset="power_law",
                params={k: float(v) for k, v in params.items()},
                N=30.0, T=5.0, tile_vertices=float(cap),
                label=f"trace/a{alpha}/t{cap}"))
            scenarios.append(Scenario.full_graph(
                args.dataflow, V=float(args.n_nodes), E=float(args.n_edges),
                N=30.0, T=5.0, tile_vertices=float(cap),
                label=f"uniform/a{alpha}/t{cap}"))

    res = evaluate_scenarios(scenarios)
    for i, row in enumerate(rows):
        tr, un = res.results[2 * i], res.results[2 * i + 1]
        row["trace_total_bits"] = tr.total_bits
        row["uniform_total_bits"] = un.total_bits
        row["uniform_over_trace_total"] = un.total_bits / tr.total_bits
        row["trace_halo_bits"] = tr.breakdown["haloreload"]
        row["uniform_halo_bits"] = un.breakdown["haloreload"]
    elapsed = time.perf_counter() - t0

    cols = list(rows[0])
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(f"# ==== analytical vs trace halo gap "
          f"(V={args.n_nodes}, E={args.n_edges}, {args.dataflow}) ====")
    print(buf.getvalue(), end="")
    worst = max(rows, key=lambda r: r["halo_estimate_over_exact"] or 0.0)
    if worst["halo_estimate_over_exact"] is None:
        # Every swept point collapsed to a single tile (capacity >= V):
        # zero halo on both sides, so there is no gap to report.
        print(f"# no inter-tile halo at any swept point ({elapsed:.2f}s)")
    else:
        print(f"# worst halo overestimate: "
              f"{worst['halo_estimate_over_exact']:.2f}x "
              f"at alpha={worst['alpha']}, "
              f"tile_vertices={worst['tile_vertices']} ({elapsed:.2f}s)")

    if args.json is not None:
        payload = {
            "benchmark": "trace_gap",
            "n_nodes": args.n_nodes,
            "n_edges": args.n_edges,
            "seed": args.seed,
            "dataflow": args.dataflow,
            "elapsed_s": elapsed,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
