"""Trace-scheduling scale benchmark: ``PYTHONPATH=src python -m benchmarks.trace_scale``.

Times the DESIGN.md §13 amortized multi-capacity trace engine against the
PR-4 per-capacity reference (one ``np.unique`` sort per capacity) on
streaming power-law graphs from 10⁵ to 10⁷ edges, across a 16-point
power-of-two tile-capacity sweep — the sweep shape the paper's
comparative question actually asks for.  For every operating point it
verifies the amortized schedules **bit-identical** to the reference
(where the reference is affordable) plus the structural invariants
(vertex/edge count conservation, ``n_tiles = ceil(V / cap)``), and exits
non-zero on any drift — the CI ``trace-scale-smoke`` gate.

Outputs one row per edge count (wall times, speedup, edges/sec) and with
``--json`` writes ``BENCH_trace_scale.json`` for PR-over-PR diffing.
``--smoke`` runs a ≤30 s budget (small graphs, reference everywhere);
the full run schedules a 10⁷-edge graph end-to-end on CPU (reference
skipped above ``--ref-max-edges``).  When the on-disk schedule cache is
enabled (``REPRO_TRACE_CACHE``), the benchmark also records cold-vs-warm
``resolve_trace_dataset`` times for the largest graph.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _pow2_caps(n_nodes: int, points: int) -> list[int]:
    """Capacities n_nodes/2, n_nodes/4, ... — ``points`` distinct values."""
    caps: list[int] = []
    i = 1
    while len(caps) < points:
        cap = max(1, n_nodes >> i)
        if caps and cap == caps[-1]:
            break  # graph too small for more distinct points
        caps.append(cap)
        i += 1
    return caps


def _check_schedules(trace, caps, scheds, refs=None) -> list[str]:
    """Drift gate: structural invariants + bit-parity vs the reference."""
    errors = []
    for cap, sched in zip(caps, scheds):
        n_tiles = -(-trace.n_nodes // cap)
        if sched.n_tiles != n_tiles:
            errors.append(f"cap={cap}: n_tiles {sched.n_tiles} != {n_tiles}")
        if int(sched.vertex_counts.sum()) != trace.n_nodes:
            errors.append(f"cap={cap}: vertex counts sum "
                          f"{int(sched.vertex_counts.sum())} != V")
        if int(sched.edge_counts.sum()) != trace.n_edges:
            errors.append(f"cap={cap}: edge counts sum "
                          f"{int(sched.edge_counts.sum())} != E")
    if refs is not None:
        for cap, sched, ref in zip(caps, scheds, refs):
            for f in ("vertex_counts", "edge_counts", "halo_counts",
                      "remote_edge_counts"):
                if not np.array_equal(getattr(sched, f), getattr(ref, f)):
                    errors.append(f"cap={cap}: {f} drifted from the "
                                  "per-capacity reference")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_trace_scale.json",
                    default=None, metavar="PATH",
                    help="also write a summary JSON "
                         "(default BENCH_trace_scale.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="small-budget CI mode (~seconds, reference "
                         "everywhere)")
    ap.add_argument("--edges", default=None,
                    help="comma-separated edge counts (overrides the "
                         "smoke/full defaults)")
    ap.add_argument("--edge-factor", type=int, default=10,
                    help="edges per vertex (n_nodes = n_edges // factor)")
    ap.add_argument("--points", type=int, default=16,
                    help="capacity-sweep points (powers of two)")
    ap.add_argument("--ref-max-edges", type=int, default=2_000_000,
                    help="largest graph to run the per-capacity reference "
                         "on (it is the slow path being replaced)")
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="amortized engine to time (jax = jitted "
                         "segment-sum path)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="cold repetitions per timing; the minimum is "
                         "reported (steadies the wall clock against "
                         "scheduler noise)")
    ap.add_argument("--alpha", type=float, default=1.6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core import schedule_cache
    from repro.core.trace import (GraphTrace, clear_trace_cache,
                                  resolve_trace_dataset)
    from repro.data import synthetic

    if args.edges is not None:
        edge_counts = [int(e) for e in args.edges.split(",")]
    elif args.smoke:
        edge_counts = [100_000, 300_000]
    else:
        edge_counts = [100_000, 1_000_000, 10_000_000]

    rows = []
    failures: list[str] = []
    for n_edges in edge_counts:
        n_nodes = max(2, n_edges // args.edge_factor)
        caps = _pow2_caps(n_nodes, args.points)

        t0 = time.perf_counter()
        snd, rcv = synthetic.power_law_edges(
            args.seed, n_nodes=n_nodes, n_edges=n_edges, alpha=args.alpha)
        t_generate = time.perf_counter() - t0

        t0 = time.perf_counter()
        trace = GraphTrace(snd, rcv, n_nodes)
        t_csr = time.perf_counter() - t0

        # Amortized engine, cold each repeat (a fresh trace drops the
        # shared factorization and schedule LRU, so every repetition pays
        # the one shared sort); minimum of the repeats is reported.
        repeats = max(1, args.repeats)
        t_amortized = None
        scheds = None
        for _ in range(repeats):
            cold = GraphTrace(snd, rcv, n_nodes)
            t0 = time.perf_counter()
            scheds = cold.schedules(caps, engine=args.engine)
            dt = time.perf_counter() - t0
            t_amortized = dt if t_amortized is None else min(t_amortized, dt)

        run_reference = n_edges <= args.ref_max_edges
        refs = None
        t_reference = None
        if run_reference:
            for _ in range(repeats):
                t0 = time.perf_counter()
                refs = [trace.schedule_reference(c) for c in caps]
                dt = time.perf_counter() - t0
                t_reference = (dt if t_reference is None
                               else min(t_reference, dt))

        errors = _check_schedules(trace, caps, scheds, refs)
        failures.extend(f"E={n_edges}: {e}" for e in errors)

        row = {
            "n_edges": n_edges,
            "n_nodes": n_nodes,
            "n_capacities": len(caps),
            "capacities": caps,
            "engine": args.engine,
            "t_generate_s": t_generate,
            "t_csr_s": t_csr,
            "t_amortized_sweep_s": t_amortized,
            "t_reference_sweep_s": t_reference,
            "speedup_vs_reference": (None if t_reference is None
                                     else t_reference / t_amortized),
            "edges_per_sec": n_edges * len(caps) / t_amortized,
            "drift_errors": errors,
        }
        rows.append(row)
        ref_txt = ("-" if t_reference is None
                   else f"{t_reference:8.3f}s  {row['speedup_vs_reference']:6.1f}x")
        print(f"E={n_edges:>9}  V={n_nodes:>8}  caps={len(caps):>2}  "
              f"gen={t_generate:6.2f}s  new={t_amortized:8.3f}s  "
              f"old/ratio={ref_txt}  "
              f"{row['edges_per_sec']:.3g} edges/s"
              + ("  DRIFT" if errors else ""))

    # Disk-cache round trip for the largest graph (only when the cache is
    # enabled and the graph clears the min-edges threshold).  The demo
    # runs against a scratch directory so the "cold" resolve is genuinely
    # cold on every invocation — a user/CI cache dir would already hold
    # the entry from a previous run and silently report warm-as-cold.
    disk = {"enabled": schedule_cache.cache_root() is not None,
            "min_edges": schedule_cache.min_cached_edges()}
    biggest = max(edge_counts)
    if disk["enabled"] and biggest >= disk["min_edges"]:
        import os
        import shutil
        import tempfile

        params = {"n_nodes": max(2, biggest // args.edge_factor),
                  "n_edges": biggest, "seed": args.seed,
                  "alpha": args.alpha}
        scratch = tempfile.mkdtemp(prefix="trace-scale-cache-")
        saved = os.environ.get("REPRO_TRACE_CACHE")
        os.environ["REPRO_TRACE_CACHE"] = scratch
        try:
            clear_trace_cache()
            t0 = time.perf_counter()
            resolve_trace_dataset("power_law_stream", params)
            disk["resolve_cold_s"] = time.perf_counter() - t0
            clear_trace_cache()
            t0 = time.perf_counter()
            resolve_trace_dataset("power_law_stream", params)
            disk["resolve_warm_s"] = time.perf_counter() - t0
            clear_trace_cache()
        finally:
            if saved is None:
                os.environ.pop("REPRO_TRACE_CACHE", None)
            else:
                os.environ["REPRO_TRACE_CACHE"] = saved
            shutil.rmtree(scratch, ignore_errors=True)
        print(f"disk cache: resolve cold {disk['resolve_cold_s']:.3f}s "
              f"-> warm {disk['resolve_warm_s']:.3f}s (scratch dir)")

    if args.json is not None:
        payload = {
            "benchmark": "trace_scale",
            "smoke": bool(args.smoke),
            "engine": args.engine,
            "repeats": max(1, args.repeats),
            "points": args.points,
            "edge_factor": args.edge_factor,
            "alpha": args.alpha,
            "seed": args.seed,
            "disk_cache": disk,
            "rows": rows,
            "drift_failures": failures,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")

    if failures:
        print("# SCHEDULE DRIFT DETECTED:")
        for e in failures:
            print(f"#   {e}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
