"""Trace-scheduling scale benchmark: ``PYTHONPATH=src python -m benchmarks.trace_scale``.

Times the DESIGN.md §13 amortized multi-capacity trace engine and the
DESIGN.md §14 **sharded streaming pipeline** against the PR-4
per-capacity reference (one ``np.unique`` sort per capacity) on
streaming power-law graphs from 10⁵ to 10⁸ edges, across a 16-point
power-of-two tile-capacity sweep — the sweep shape the paper's
comparative question actually asks for.

Every edge count runs the sharded pipeline (per-shard generation +
local sort → range-bucketed exchange → per-bucket factorization → O(U)
CSR → ``engine="sharded"`` capacity sweep) with per-stage wall times
and peak-RSS snapshots.  Up to ``--single-max-edges`` it *also* runs
the single-host path and enforces the distributed drift gate: the
sharded factorization must be **bit-identical** (values, order, dtypes)
to the single-host one, and every sharded schedule bit-identical to the
amortized engine and (up to ``--ref-max-edges``) to the PR-4 reference,
plus the structural invariants (vertex/edge count conservation,
``n_tiles = ceil(V / cap)``).  Exits non-zero on any drift — the CI
``trace-scale-smoke`` / ``trace-shard-smoke`` gates.

Outputs one row per edge count and with ``--json`` writes
``BENCH_trace_scale.json`` for PR-over-PR diffing.  ``--smoke`` runs a
≤30 s budget (small graphs, reference everywhere); the full run
schedules a 10⁸-edge graph end-to-end through the sharded path alone.
When the on-disk schedule cache is enabled (``REPRO_TRACE_CACHE``), the
benchmark also records cold-vs-warm ``resolve_trace_dataset`` times for
the largest single-host graph (warm resolves are mmap-lazy in cache
format v2, so the warm number is size-independent).

Peak-RSS note: ``ru_maxrss`` is a process-lifetime high-water mark, so
per-stage values are monotone "peak so far" snapshots — the first stage
that spikes shows where the ceiling came from.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _pow2_caps(n_nodes: int, points: int) -> list[int]:
    """Capacities n_nodes/2, n_nodes/4, ... — ``points`` distinct values."""
    caps: list[int] = []
    i = 1
    while len(caps) < points:
        cap = max(1, n_nodes >> i)
        if caps and cap == caps[-1]:
            break  # graph too small for more distinct points
        caps.append(cap)
        i += 1
    return caps


def _check_schedules(trace, caps, scheds, refs=None,
                     label: str = "per-capacity reference") -> list[str]:
    """Drift gate: structural invariants + bit-parity vs a reference."""
    errors = []
    for cap, sched in zip(caps, scheds):
        n_tiles = -(-trace.n_nodes // cap)
        if sched.n_tiles != n_tiles:
            errors.append(f"cap={cap}: n_tiles {sched.n_tiles} != {n_tiles}")
        if int(sched.vertex_counts.sum()) != trace.n_nodes:
            errors.append(f"cap={cap}: vertex counts sum "
                          f"{int(sched.vertex_counts.sum())} != V")
        if int(sched.edge_counts.sum()) != trace.n_edges:
            errors.append(f"cap={cap}: edge counts sum "
                          f"{int(sched.edge_counts.sum())} != E")
    if refs is not None:
        for cap, sched, ref in zip(caps, scheds, refs):
            for f in ("vertex_counts", "edge_counts", "halo_counts",
                      "remote_edge_counts"):
                if not np.array_equal(getattr(sched, f), getattr(ref, f)):
                    errors.append(f"cap={cap}: {f} drifted from the {label}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_trace_scale.json",
                    default=None, metavar="PATH",
                    help="also write a summary JSON "
                         "(default BENCH_trace_scale.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="small-budget CI mode (~seconds, reference "
                         "everywhere)")
    ap.add_argument("--edges", default=None,
                    help="comma-separated edge counts (overrides the "
                         "smoke/full defaults)")
    ap.add_argument("--edge-factor", type=int, default=10,
                    help="edges per vertex (n_nodes = n_edges // factor)")
    ap.add_argument("--points", type=int, default=16,
                    help="capacity-sweep points (powers of two)")
    ap.add_argument("--ref-max-edges", type=int, default=2_000_000,
                    help="largest graph to run the per-capacity reference "
                         "on (it is the slow path being replaced)")
    ap.add_argument("--single-max-edges", type=int, default=10_000_000,
                    help="largest graph to run the single-host pipeline on "
                         "(above this only the sharded path runs; the "
                         "drift gate needs both)")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count for the sharded pipeline (default: "
                         "REPRO_TRACE_SHARDS, else the CPU count)")
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="single-host amortized engine to time (jax = "
                         "jitted segment-sum path)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="cold repetitions per timing; the minimum is "
                         "reported (steadies the wall clock against "
                         "scheduler noise)")
    ap.add_argument("--alpha", type=float, default=1.6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core import schedule_cache
    from repro.core.trace import (GraphTrace, clear_trace_cache,
                                  resolve_trace_dataset)
    from repro.data import synthetic
    from repro.distributed import trace_shard

    if args.edges is not None:
        edge_counts = [int(e) for e in args.edges.split(",")]
    elif args.smoke:
        edge_counts = [100_000, 300_000]
    else:
        edge_counts = [100_000, 1_000_000, 10_000_000, 100_000_000]

    n_shards = (args.shards if args.shards is not None
                else trace_shard.default_shard_count())
    repeats = max(1, args.repeats)
    rows = []
    failures: list[str] = []
    for n_edges in edge_counts:
        n_nodes = max(2, n_edges // args.edge_factor)
        caps = _pow2_caps(n_nodes, args.points)
        rss = {}

        # -- sharded pipeline (always): generation+sort, exchange, CSR --
        shard_stats: dict = {}
        strace = trace_shard.build_power_law_trace(
            n_nodes=n_nodes, n_edges=n_edges, seed=args.seed,
            alpha=args.alpha, n_shards=n_shards, stats=shard_stats)
        rss["shard_generate_sort_kb"] = shard_stats["rss_generate_sort_kb"]
        rss["shard_exchange_factorize_kb"] = (
            shard_stats["rss_exchange_factorize_kb"])
        rss["shard_csr_kb"] = shard_stats["rss_csr_kb"]

        t_sharded_sweep = None
        sharded_scheds = None
        for _ in range(repeats):
            strace.clear_schedules()  # factorization stays: timed above
            t0 = time.perf_counter()
            sharded_scheds = strace.schedules(caps, engine="sharded")
            dt = time.perf_counter() - t0
            t_sharded_sweep = (dt if t_sharded_sweep is None
                               else min(t_sharded_sweep, dt))
        rss["shard_sweep_kb"] = trace_shard._peak_rss_kb()
        t_total_sharded = (shard_stats["t_generate_sort_s"]
                           + shard_stats["t_exchange_factorize_s"]
                           + shard_stats["t_csr_s"] + t_sharded_sweep)

        errors = _check_schedules(strace, caps, sharded_scheds)

        # -- single-host pipeline + drift gates (bounded sizes) ----------
        run_single = n_edges <= args.single_max_edges
        t_generate = t_csr = t_amortized = t_reference = None
        t_total_single = None
        if run_single:
            t0 = time.perf_counter()
            snd, rcv = synthetic.power_law_edges(
                args.seed, n_nodes=n_nodes, n_edges=n_edges,
                alpha=args.alpha)
            t_generate = time.perf_counter() - t0
            rss["generate_kb"] = trace_shard._peak_rss_kb()

            t0 = time.perf_counter()
            trace = GraphTrace(snd, rcv, n_nodes)
            t_csr = time.perf_counter() - t0
            rss["csr_kb"] = trace_shard._peak_rss_kb()

            # Amortized engine, cold each repeat (a fresh trace drops the
            # shared factorization and schedule LRU, so every repetition
            # pays the one shared sort); minimum of the repeats reported.
            scheds = None
            for _ in range(repeats):
                cold = GraphTrace(snd, rcv, n_nodes)
                t0 = time.perf_counter()
                scheds = cold.schedules(caps, engine=args.engine)
                dt = time.perf_counter() - t0
                t_amortized = (dt if t_amortized is None
                               else min(t_amortized, dt))
            rss["sweep_kb"] = trace_shard._peak_rss_kb()
            t_total_single = t_generate + t_csr + t_amortized

            # Distributed drift gate 1: the sharded factorization is
            # bit-identical (values, order, dtypes) to the single-host
            # one for this shard count.
            u_snd, u_rcv, _, mp = trace._pair_factorization()
            su_snd, su_rcv, _, smp = strace._pair_factorization()
            errors += [f"factorization: {e}" for e in
                       trace_shard.factorization_drift(
                           (su_snd, su_rcv, smp), (u_snd, u_rcv, mp))]
            # Drift gate 2: sharded schedules == amortized engine.
            errors += _check_schedules(strace, caps, sharded_scheds, scheds,
                                       label="single-host amortized engine")

            if n_edges <= args.ref_max_edges:
                refs = None
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    refs = [trace.schedule_reference(c) for c in caps]
                    dt = time.perf_counter() - t0
                    t_reference = (dt if t_reference is None
                                   else min(t_reference, dt))
                errors += _check_schedules(trace, caps, scheds, refs)
                # Drift gate 3: sharded schedules == PR-4 oracle.
                errors += _check_schedules(
                    strace, caps, sharded_scheds, refs,
                    label="schedule_reference oracle")

        failures.extend(f"E={n_edges}: {e}" for e in errors)

        row = {
            "n_edges": n_edges,
            "n_nodes": n_nodes,
            "n_capacities": len(caps),
            "capacities": caps,
            "engine": args.engine,
            "n_shards": shard_stats["n_shards"],
            "n_unique_pairs": shard_stats["n_unique_pairs"],
            "t_shard_generate_sort_s": shard_stats["t_generate_sort_s"],
            "t_shard_exchange_factorize_s": (
                shard_stats["t_exchange_factorize_s"]),
            "t_shard_csr_s": shard_stats["t_csr_s"],
            "t_sharded_sweep_s": t_sharded_sweep,
            "t_total_sharded_s": t_total_sharded,
            "t_generate_s": t_generate,
            "t_csr_s": t_csr,
            "t_amortized_sweep_s": t_amortized,
            "t_total_single_s": t_total_single,
            "t_reference_sweep_s": t_reference,
            "speedup_vs_reference": (None if t_reference is None
                                     else t_reference / t_amortized),
            "edges_per_sec": n_edges * len(caps) / t_sharded_sweep,
            "rss_peak_kb": rss,
            "drift_errors": errors,
        }
        rows.append(row)
        single_txt = ("-" if t_total_single is None
                      else f"{t_total_single:7.2f}s")
        ref_txt = ("-" if t_reference is None
                   else f"{row['speedup_vs_reference']:6.1f}x")
        print(f"E={n_edges:>9}  V={n_nodes:>8}  caps={len(caps):>2}  "
              f"shards={shard_stats['n_shards']}  "
              f"sharded={t_total_sharded:7.2f}s  single={single_txt}  "
              f"sweep={t_sharded_sweep:6.3f}s  old/ratio={ref_txt}"
              + ("  DRIFT" if errors else ""))

    # Disk-cache round trip for the largest single-host graph (only when
    # the cache is enabled and the graph clears the min-edges threshold).
    # The demo runs against a scratch directory so the "cold" resolve is
    # genuinely cold on every invocation — a user/CI cache dir would
    # already hold the entry from a previous run and silently report
    # warm-as-cold.
    disk = {"enabled": schedule_cache.cache_root() is not None,
            "min_edges": schedule_cache.min_cached_edges()}
    biggest = max([e for e in edge_counts if e <= args.single_max_edges],
                  default=0)
    if disk["enabled"] and biggest >= disk["min_edges"] > 0:
        import os
        import shutil
        import tempfile

        params = {"n_nodes": max(2, biggest // args.edge_factor),
                  "n_edges": biggest, "seed": args.seed,
                  "alpha": args.alpha}
        scratch = tempfile.mkdtemp(prefix="trace-scale-cache-")
        saved = os.environ.get("REPRO_TRACE_CACHE")
        os.environ["REPRO_TRACE_CACHE"] = scratch
        try:
            clear_trace_cache()
            t0 = time.perf_counter()
            resolve_trace_dataset("power_law_stream", params)
            disk["resolve_cold_s"] = time.perf_counter() - t0
            clear_trace_cache()
            t0 = time.perf_counter()
            warm = resolve_trace_dataset("power_law_stream", params)
            disk["resolve_warm_s"] = time.perf_counter() - t0
            # Warm resolves are lazy; charge the deferred factorization
            # finish + one schedule separately so laziness can't hide a
            # regression behind an untouched mmap.
            t0 = time.perf_counter()
            warm.schedule(max(2, params["n_nodes"] // 4))
            disk["warm_first_schedule_s"] = time.perf_counter() - t0
            clear_trace_cache()
        finally:
            if saved is None:
                os.environ.pop("REPRO_TRACE_CACHE", None)
            else:
                os.environ["REPRO_TRACE_CACHE"] = saved
            shutil.rmtree(scratch, ignore_errors=True)
        print(f"disk cache: resolve cold {disk['resolve_cold_s']:.3f}s "
              f"-> warm {disk['resolve_warm_s']:.4f}s (mmap-lazy; first "
              f"schedule +{disk['warm_first_schedule_s']:.3f}s)")

    if args.json is not None:
        payload = {
            "benchmark": "trace_scale",
            "smoke": bool(args.smoke),
            "engine": args.engine,
            "n_shards": n_shards,
            "repeats": repeats,
            "points": args.points,
            "edge_factor": args.edge_factor,
            "alpha": args.alpha,
            "seed": args.seed,
            "disk_cache": disk,
            "rows": rows,
            "drift_failures": failures,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")

    if failures:
        print("# SCHEDULE DRIFT DETECTED:")
        for e in failures:
            print(f"#   {e}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
