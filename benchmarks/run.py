"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints one CSV per paper table/figure (name,us_per_call,derived columns)
followed by the §Roofline table derived from the dry-run artifacts (if
present).  Every benchmark is a scenario batch through the ``repro.api``
front door (DESIGN.md §11) — the figures via their named templates, the
composition and workload studies as explicit batches; ``python -m
repro.api`` replays any of them from JSON.  Use ``--figure figN`` (fig3..
fig7, sweep_all, cora_end_to_end, workloads) / ``--skip-roofline`` to
subset, and ``--json [PATH]`` to additionally emit a machine-readable
timing summary (default ``BENCH_sweep.json``) covering fig3-fig7 plus the
all-accelerator, full-graph composition, and workload-bridge sweeps —
future PRs diff this file for the sweep engine's perf trajectory.  The
JSON also carries a ``conformance`` block (one small measured-vs-modeled
operating point, DESIGN.md §10); ``--skip-conformance`` drops it, and
``python -m benchmarks.conformance`` runs the full sweep.  An
``analysis`` block summarizes the static model audit (DESIGN.md §16:
per-dataflow unit/dead-hw/overflow counts, lint violations, mutation
battery); ``--skip-analysis`` drops it, and ``python -m repro.analysis``
is the full gate.
"""

from __future__ import annotations

import argparse
import csv
import io
import json


def _print_csv(name: str, rows: list[dict]) -> None:
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = sorted({k for r in rows for k in r})
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(f"# ==== {name} ({len(rows)} rows) ====")
    print(buf.getvalue())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--figure", default=None,
                    help="only this benchmark (fig3..fig7, sweep_all, "
                         "cora_end_to_end, workloads)")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-conformance", action="store_true",
                    help="omit the conformance summary block from --json")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="omit the model-audit summary block from --json")
    ap.add_argument("--json", nargs="?", const="BENCH_sweep.json", default=None,
                    metavar="PATH",
                    help="also write a timing summary JSON (default "
                         "BENCH_sweep.json)")
    args = ap.parse_args()

    from . import paper_figures

    summary: dict[str, dict] = {}
    for fn in paper_figures.ALL:
        if args.figure and fn.__name__ != args.figure:
            continue
        rows = fn()
        _print_csv(fn.__name__, rows)
        # Keyed by the per-row figure label so independently-timed
        # sub-benchmarks (fig5 times engn and hygcn separately) each keep
        # their own perf-trajectory entry.
        for r in rows:
            entry = summary.setdefault(
                str(r.get("figure", fn.__name__)),
                {"us_per_call": r.get("us_per_call"), "n_rows": 0})
            entry["n_rows"] += 1

    if args.json is not None:
        payload = {"benchmarks": summary}
        if not args.skip_conformance:
            from repro.core.conformance import (OperatingPoint,
                                                run_conformance,
                                                summarize_records)
            records = run_conformance(
                points=(OperatingPoint(256, 16, 8, 128, 128),))
            payload["conformance"] = summarize_records(records)
        if not args.skip_analysis:
            from repro.analysis import (audit_registry, lint_paths,
                                        run_mutation_battery)
            audits = audit_registry()
            outcomes = run_mutation_battery()
            payload["analysis"] = {
                "dataflows": {
                    name: {"ok": a.ok,
                           "unit_errors": a.unit_error_count,
                           "waived_unit_issues": a.waived_issue_count,
                           "overflow_findings": a.overflow_count,
                           "dead_hw": list(a.dead_hw),
                           "waived_dead_hw": list(a.waived_dead_hw)}
                    for name, a in sorted(audits.items())},
                "lint_violations": len(lint_paths()),
                "mutants_caught": sum(o.caught for o in outcomes),
                "mutants_total": len(outcomes),
            }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json} ({len(summary)} benchmarks)")

    if not args.skip_roofline and not args.figure:
        from . import roofline
        try:
            table_rows = roofline.rows("single")
        except FileNotFoundError:
            table_rows = []
        if table_rows:
            _print_csv("roofline_single_pod", table_rows)
            print("# roofline table (human-readable):")
            print(roofline.render_table())
        else:
            print("# roofline: no dry-run artifacts "
                  "(run PYTHONPATH=src python -m repro.launch.dryrun)")


if __name__ == "__main__":
    main()
