"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints one CSV per paper table/figure (name,us_per_call,derived columns)
followed by the §Roofline table derived from the dry-run artifacts (if
present).  Use ``--figure figN`` / ``--skip-roofline`` to subset.
"""

from __future__ import annotations

import argparse
import csv
import io


def _print_csv(name: str, rows: list[dict]) -> None:
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = sorted({k for r in rows for k in r})
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(f"# ==== {name} ({len(rows)} rows) ====")
    print(buf.getvalue())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--figure", default=None,
                    help="only this figure (fig3..fig7)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from . import paper_figures

    for fn in paper_figures.ALL:
        if args.figure and fn.__name__ != args.figure:
            continue
        _print_csv(fn.__name__, fn())

    if not args.skip_roofline and not args.figure:
        from . import roofline
        try:
            table_rows = roofline.rows("single")
        except FileNotFoundError:
            table_rows = []
        if table_rows:
            _print_csv("roofline_single_pod", table_rows)
            print("# roofline table (human-readable):")
            print(roofline.render_table())
        else:
            print("# roofline: no dry-run artifacts "
                  "(run PYTHONPATH=src python -m repro.launch.dryrun)")


if __name__ == "__main__":
    main()
